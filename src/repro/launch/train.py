"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Trains a masked-diffusion LM on the synthetic task suite (the band-2
quality testbed) or, with ``--dryrun-mesh``, lowers the same train_step on
the production mesh instead of executing it.
"""
from __future__ import annotations

import argparse

from repro.configs import TrainConfig, get_config
from repro.data import CharTokenizer, TaskDataset
from repro.training.trainer import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b",
                    help="architecture id (use '<id>-tiny' for reduced)")
    ap.add_argument("--task", default="sum",
                    choices=["sum", "sort", "parity", "bracket", "reverse"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    tok = CharTokenizer(cfg.vocab_size)
    ds = TaskDataset(args.task, tok)
    tcfg = TrainConfig(batch_size=args.batch, seq_len=ds.seq_len,
                       steps=args.steps, lr=args.lr, seed=args.seed,
                       ckpt_dir=args.ckpt)
    print(f"training {cfg.name} ({cfg.param_count() / 1e6:.1f} M params) "
          f"on task '{args.task}' for {tcfg.steps} steps")
    params, history = train(cfg, tcfg, ds.batches(tcfg.batch_size))
    print(f"final loss {history['loss'][-1]:.4f} "
          f"masked-acc {history['acc'][-1]:.3f}")


if __name__ == "__main__":
    main()
