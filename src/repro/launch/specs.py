"""ShapeDtypeStruct stand-ins + shardings for every dry-run combination.

``input_specs(cfg, shape_name, mesh)`` returns (step_kind, args, in_specs,
out_specs) where ``args`` is a pytree of ShapeDtypeStruct — weak-type
correct, shardable, zero device allocation — and the spec trees mirror it.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import init_decode_state, init_model
from repro.parallel.sharding import (batch_pspec, cache_pspecs, data_axes,
                                     param_pspecs)
from repro.training.optimizer import adamw_init

# shape id -> (step kind, seq_len, global_batch)
SHAPES: Dict[str, Tuple[str, int, int]] = {
    "train_4k":    ("train",   4_096, 256),
    "prefill_32k": ("prefill", 32_768, 32),
    "decode_32k":  ("serve",   32_768, 128),
    "long_500k":   ("serve",   524_288, 1),
}


class SpecBundle(NamedTuple):
    kind: str
    args: Tuple            # positional args for the step fn (SDS pytrees)
    in_specs: Tuple        # matching PartitionSpec pytrees
    out_specs: Any         # PartitionSpec pytree or None (compiler choice)


def shape_admissible(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §Arch-applicability)."""
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_spec(cfg: ModelConfig, mesh):
    """(params SDS tree, PartitionSpec tree) without allocating anything."""
    p_sds = jax.eval_shape(
        functools.partial(init_model, cfg=cfg), jax.random.PRNGKey(0))
    return p_sds, param_pspecs(p_sds, mesh)


def _extras_sds(cfg: ModelConfig, batch: int):
    """Stub-frontend inputs: precomputed frame / patch embeddings."""
    out = {}
    if cfg.encdec is not None and cfg.encdec.frontend == "audio_stub":
        out["enc_embeds"] = _sds((batch, cfg.encdec.encoder_seq,
                                  cfg.d_model), jnp.bfloat16)
    if cfg.encdec is not None and cfg.encdec.frontend == "vision_stub":
        out["patch_embeds"] = _sds((batch, cfg.encdec.num_patch_tokens,
                                    cfg.d_model), jnp.bfloat16)
    return out


def input_specs(cfg: ModelConfig, shape_name: str, mesh,
                opts: frozenset = frozenset()) -> SpecBundle:
    """``opts`` (perf-pass knobs, see EXPERIMENTS.md §Perf):
      infer_replicate — prefill/serve weights NOT FSDP-sharded on data
                        (inference has no optimizer state to amortize;
                        replication kills the per-layer all-gathers);
      infer_bf16      — prefill/serve weights stored bf16 (a serving
                        checkpoint), halving weight bytes.
    """
    kind, seq, batch = SHAPES[shape_name]
    p_sds, p_spec = params_spec(cfg, mesh)
    if kind != "train":
        if "infer_replicate" in opts:
            p_spec = param_pspecs(p_sds, mesh, fsdp=False)
        if "infer_bf16" in opts:
            p_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                if s.dtype == jnp.float32 else s, p_sds)
    if kind == "train":
        opt_sds = jax.eval_shape(adamw_init, p_sds)
        opt_spec = type(opt_sds)(step=P(), mu=p_spec, nu=p_spec)
        rng_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
        batch_sds = {"tokens": _sds((batch, seq), jnp.int32),
                     "maskable": _sds((batch, seq), jnp.bool_),
                     **_extras_sds(cfg, batch)}
        # batch on the data axes; sequence parallelism (activation
        # constraints inside the model) shards the seq axis on `model`
        batch_spec = {k: batch_pspec(mesh, ndim=len(v.shape))
                      for k, v in batch_sds.items()}
        args = (p_sds, opt_sds, rng_sds, batch_sds)
        in_specs = (p_spec, opt_spec, P(), batch_spec)
        out_specs = (p_spec, opt_spec,
                     {"loss": P(), "aux": P(), "acc": P()})
        return SpecBundle("train", args, in_specs, out_specs)

    if kind == "prefill":
        batch_sds = {"tokens": _sds((batch, seq), jnp.int32),
                     **_extras_sds(cfg, batch)}
        batch_spec = {k: batch_pspec(mesh, ndim=len(v.shape))
                      for k, v in batch_sds.items()}
        args = (p_sds, batch_sds)
        in_specs = (p_spec, batch_spec)
        # Scores: 4 × (B, L) — replicate-free: batch on data
        out_specs = None
        return SpecBundle("prefill", args, in_specs, out_specs)

    # serve: ONE new token vs a cache/state of length `seq`
    def build_state():
        enc = None
        if cfg.encdec is not None and cfg.encdec.frontend == "audio_stub":
            enc = jnp.zeros((batch, cfg.encdec.encoder_seq, cfg.d_model),
                            jnp.bfloat16)
        return init_decode_state(cfg, batch, seq, jnp.bfloat16, enc_out=enc)

    state_sds = jax.eval_shape(build_state)
    state_spec = cache_pspecs(state_sds, mesh, batch)
    token_sds = _sds((batch, 1), jnp.int32)
    pos_sds = _sds((batch, 1), jnp.int32)
    dsize = 1
    for a in data_axes(mesh):
        dsize *= mesh.shape[a]
    tok_sp = batch_pspec(mesh) if batch % dsize == 0 else P()
    args = (p_sds, token_sds, pos_sds, state_sds)
    in_specs = (p_spec, tok_sp, tok_sp, state_spec)
    out_specs = None
    return SpecBundle("serve", args, in_specs, out_specs)
