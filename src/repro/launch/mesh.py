"""Production meshes for the TPU v5e target.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod is 256 chips as (16, 16) -> ("data","model"),
multi-pod is 2 pods = 512 chips as (2, 16, 16) -> ("pod","data","model").
The dry-run script materializes these over 512 forced host-platform
devices; real launches get them from the TPU topology.
"""
from __future__ import annotations

import jax

# hardware constants (TPU v5e) used by the roofline analysis
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1x1 mesh over the real local device (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
