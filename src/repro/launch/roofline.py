"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs   / PEAK_FLOPS          (per-chip program)
    memory     = HLO_bytes   / HBM_BW
    collective = coll_bytes  / ICI_BW

``cost_analysis`` on a GSPMD-partitioned executable describes the
*per-device* module, so the terms above are already per-chip; collective
bytes are parsed from the compiled HLO text (sum of output sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
which approximates the per-chip link traffic).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per training step —
the "useful"-compute yardstick; its ratio against total-step HLO FLOPs
flags remat/redundancy waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum output sizes of every collective op in the HLO text."""
    per_kind: Dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", stripped)
        if not m:
            continue
        size = _shape_bytes(m.group(1))
        per_kind[m.group(2)] += size
        ops += 1
    return sum(per_kind.values()), per_kind


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                  # per-chip HLO FLOPs
    bytes_accessed: float         # per-chip HBM traffic
    coll_bytes: float             # per-chip link traffic
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0      # 6·N_active·D (global, per step)
    peak_memory: Optional[float] = None   # bytes/device, from memory_analysis
    args_bytes: Optional[float] = None    # params + caches per device

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (remat & redundancy waste)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.t_compute * 1e3:.2f} | {self.t_memory * 1e3:.2f} | "
                f"{self.t_collective * 1e3:.2f} | **{self.bottleneck}** | "
                f"{self.useful_ratio:.3f} |")


def model_flops_per_step(cfg: ModelConfig, shape_kind: str, seq: int,
                         batch: int) -> float:
    """6·N_active·D for train (fwd+bwd), 2·N_active·D for inference."""
    n = cfg.active_param_count()
    if shape_kind == "train":
        d = batch * seq
        return 6.0 * n * d
    if shape_kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch * 1       # serve: one token


def measure(compiled) -> Tuple[float, float, float, Dict[str, int]]:
    """(flops, bytes, collective_bytes, breakdown) of a compiled program.

    NOTE: XLA's cost_analysis counts a while/scan body ONCE, not × trip
    count (verified empirically) — callers that scan over layers must
    extrapolate per-layer costs; see ``dryrun.roofline_extrapolated``.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll, breakdown = collective_bytes(hlo)
    return flops, bytes_accessed, float(coll), breakdown


def peak_memory(compiled) -> Optional[float]:
    try:
        ma = compiled.memory_analysis()
        return (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes)
    except Exception:
        return None
