"""Serving CLI: train small LLDM(s), then serve them over HTTP/SSE.

    PYTHONPATH=src python -m repro.launch.serve \
        --models tiny=llada-8b-tiny:sum --port 8000 --budget-mb 64

Starts the full stack — ``ModelRouter`` (bytes-budget LRU over engines)
→ ``AsyncScheduler`` per model (continuous batching, admission control)
→ stdlib HTTP/1.1 + SSE server — and prints copy-paste ``curl`` lines.
Per-request decode knobs (``strategy`` / ``steps`` / ``gen_length`` /
``block_size``) ride the JSON body; see ``repro/serving/server.py`` for
the endpoint surface.

``--selftest`` instead boots the server on an ephemeral port, runs one
streamed request through the blocking client, prints the events, and
exits — the offline end-to-end sanity check.

SIGTERM / SIGINT drain gracefully: admission stops (new submits answer
503 + Retry-After), in-flight and queued requests get up to the drain
deadline (``SupervisorConfig.drain_deadline_s``) to finish, leftover
streams receive terminal ``shutdown`` events, then the process exits.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import signal
import tempfile

import jax

from repro.configs import (DecodeConfig, RouterConfig, ServerConfig,
                           TrainConfig, default_block_size, get_config)
from repro.data import CharTokenizer, TaskDataset
from repro.serving import (ModelRouter, ServerThread, ServingClient,
                           ServingEngine, ServingServer)
from repro.training import load, save
from repro.training.trainer import train


def build_model(arch: str, task: str, train_steps: int, strategy: str,
                ckpt_dir: str):
    """Train a small model on a task and PARK IT ON DISK; returns
    ``(ckpt_path, cfg, dcfg, tok, ds)``.  The registered engine factory
    loads from the checkpoint, so the factory closure never pins the
    params in RAM — otherwise the router's ``--budget-mb`` eviction
    would free nothing (the weak runner cache anchors on the params
    leaves, and a factory default holding them keeps every finalizer
    unfireable)."""
    cfg = get_config(arch)
    tok = CharTokenizer(cfg.vocab_size)
    ds = TaskDataset(task, tok)
    tcfg = TrainConfig(batch_size=64, seq_len=ds.seq_len,
                       steps=train_steps)
    print(f"warm-up training {cfg.name} on '{task}' ({tcfg.steps} steps)…")
    params, _ = train(cfg, tcfg, ds.batches(tcfg.batch_size))
    path = os.path.join(ckpt_dir, f"{cfg.name}-{task}.npz")
    save(path, params, step=train_steps)
    del params
    gen = ds.seq_len - (1 + ds.prompt_len)
    dcfg = DecodeConfig(gen_length=gen,
                        block_size=default_block_size(gen), steps=gen,
                        strategy=strategy)
    return path, cfg, dcfg, tok, ds


def load_engine(ckpt_path: str, cfg, dcfg, max_batch: int
                ) -> ServingEngine:
    """Engine factory body: load the checkpoint (template pytree from a
    fresh init) and wrap it — called per (re)build by the router."""
    from repro.models.model import init_model
    params, _, _ = load(ckpt_path,
                        init_model(jax.random.PRNGKey(0), cfg))
    return ServingEngine(params, cfg, dcfg, max_batch=max_batch)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="tiny=llada-8b-tiny:sum",
                    help="comma list of name=arch:task model specs")
    ap.add_argument("--strategy", default="fdm_a",
                    help="default decode strategy (per-request override "
                         "via the 'strategy' JSON field)")
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--budget-mb", type=int, default=0,
                    help="router residency budget in MiB (0 = unlimited)")
    ap.add_argument("--max-queue-depth", type=int, default=64)
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="default max queued seconds per request")
    ap.add_argument("--selftest", action="store_true",
                    help="serve on an ephemeral port, run one streamed "
                         "request, print its events, exit")
    args = ap.parse_args()

    router = ModelRouter(RouterConfig(
        budget_bytes=args.budget_mb << 20))
    ckpt_dir = tempfile.mkdtemp(prefix="repro-serve-")
    tokenizer = None
    first_ds = None
    for spec in args.models.split(","):
        name, _, rest = spec.partition("=")
        arch, _, task = rest.partition(":")
        if not (name and arch):
            raise SystemExit(f"bad --models entry {spec!r} "
                             f"(want name=arch:task)")
        path, cfg, dcfg, tok, ds = build_model(
            arch, task or "sum", args.train_steps, args.strategy,
            ckpt_dir)
        if tokenizer is None:
            tokenizer, first_ds = tok, ds
        # the factory loads from disk: evicted models genuinely free
        # their weights and rebuild on demand from the checkpoint
        router.register(
            name,
            lambda p=path, c=cfg, d=dcfg: load_engine(
                p, c, d, args.max_batch))

    scfg = ServerConfig(host=args.host,
                        port=0 if args.selftest else args.port,
                        max_queue_depth=args.max_queue_depth,
                        default_deadline_s=args.deadline_s)
    if args.selftest:
        _selftest(router, scfg, tokenizer, first_ds)
        return

    async def serve() -> None:
        server = ServingServer(router, scfg, tokenizer=tokenizer)
        host, port = await server.start()
        loop = asyncio.get_running_loop()
        drained = loop.create_future()

        def _on_sigterm() -> None:
            # graceful drain: admission stops (503 + Retry-After),
            # in-flight work finishes within the drain deadline,
            # leftover streams get terminal `shutdown` events
            if not drained.done():
                print("SIGTERM: draining "
                      f"(deadline {scfg.supervisor.drain_deadline_s:g}s)…")
                drained.set_result(None)

        try:
            loop.add_signal_handler(signal.SIGTERM, _on_sigterm)
            loop.add_signal_handler(signal.SIGINT, _on_sigterm)
        except NotImplementedError:
            pass                        # non-Unix event loop
        base = f"http://{host}:{port}"
        example = first_ds.prompts_only(
            first_ds.eval_batch(1))[0].tolist()
        print(f"serving {router.names()} on {base}")
        print("try:")
        print(f"  curl {base}/healthz")
        print(f"  curl -N -X POST {base}/v1/generate "
              f"-d '{json.dumps({'prompt': example, 'wait': True})}'")
        print(f"  rid=$(curl -s -X POST {base}/v1/generate "
              f"-d '{json.dumps({'prompt': example})}' "
              "| python -c 'import sys,json;"
              "print(json.load(sys.stdin)[\"rid\"])')")
        print(f"  curl -N {base}/v1/stream/$rid        # SSE blocks")
        print(f"  curl {base}/metrics")
        serve_task = asyncio.ensure_future(server.serve_forever())
        await drained
        # drain BEFORE tearing the accept loop down: open SSE readers
        # keep their connections and collect terminal events during the
        # drain window; only then does the listener close
        await server.drain()
        serve_task.cancel()
        try:
            await serve_task
        except (asyncio.CancelledError, RuntimeError):
            pass
        print("drained; bye")

    try:
        asyncio.run(serve())
    except KeyboardInterrupt:
        print("\nbye")


def _selftest(router: ModelRouter, scfg: ServerConfig, tokenizer,
              ds) -> None:
    handle = ServerThread(router, scfg, tokenizer=tokenizer).start()
    try:
        client = ServingClient(handle.host, handle.port)
        print("healthz:", client.healthz())
        prompt = ds.prompts_only(ds.eval_batch(1))[0].tolist()
        print(f"streaming one request (prompt "
              f"{tokenizer.decode(prompt)!r}) …")
        for name, event in client.generate_stream(prompt):
            if name == "block":
                print(f"  block {event['block']} cols "
                      f"[{event['lo']}:{event['hi']}] "
                      f"-> {event.get('text', event['tokens'])!r}")
            else:
                print(f"  {name}: status={event.get('status')} "
                      f"latency={event.get('latency_s', 0):.3f}s")
        print("metrics head:")
        print("\n".join(client.metrics_text().splitlines()[:8]))
    finally:
        handle.stop()
    print("selftest OK")


if __name__ == "__main__":
    main()
