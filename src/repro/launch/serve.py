"""Serving launcher: train a small LLDM then serve batched requests with a
chosen decoding strategy through the ServingEngine (which decodes through
the first-class ``repro.core.Decoder`` stack).

``python -m repro.launch.serve --strategy fdm_a --requests 16``

``--stream`` prints each committed block as it lands (the engine's
``on_block_committed`` hook — the SSE grain of blockwise diffusion
decoding).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import DecodeConfig, TrainConfig, get_config
from repro.data import CharTokenizer, TaskDataset
from repro.serving import ServingEngine
from repro.training.trainer import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b-tiny")
    ap.add_argument("--task", default="sum")
    ap.add_argument("--strategy", default="fdm_a")
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--stream", action="store_true",
                    help="print per-block commit events while decoding")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    tok = CharTokenizer(cfg.vocab_size)
    ds = TaskDataset(args.task, tok)
    tcfg = TrainConfig(batch_size=64, seq_len=ds.seq_len,
                       steps=args.train_steps)
    print(f"warm-up training {cfg.name} on '{args.task}' "
          f"({tcfg.steps} steps)…")
    params, _ = train(cfg, tcfg, ds.batches(tcfg.batch_size))

    gen = ds.seq_len - (1 + ds.prompt_len)
    block = max(gen // 2, 1)
    dcfg = DecodeConfig(gen_length=gen, block_size=block, steps=gen,
                        strategy=args.strategy)
    stream_cb = None
    if args.stream:
        def stream_cb(reqs, blk, lo, hi, x):
            print(f"  [stream] batch of {len(reqs)} committed block {blk} "
                  f"(cols {lo}:{hi})")
    engine = ServingEngine(params, cfg, dcfg, max_batch=args.max_batch,
                           on_block_committed=stream_cb)

    batch = ds.eval_batch(args.requests)
    prompts = ds.prompts_only(batch)
    for i in range(args.requests):
        engine.submit(prompts[i])
    engine.run_until_idle()

    outs = np.stack([engine.result(i).result for i in range(args.requests)])
    em = ds.exact_match(outs, batch)
    print(f"strategy={args.strategy}  exact-match {em:.2%}")
    print("engine summary:", engine.summary())
    for i in range(min(3, args.requests)):
        r = engine.result(i)
        print(f"  [{i}] prompt={tok.decode(prompts[i])!r} "
              f"-> answer={tok.decode(r.result[ds.answer_slice])!r} "
              f"latency={r.latency:.2f}s")


if __name__ == "__main__":
    main()
