"""The three production step functions, one semantics for tests/examples
and the multi-pod dry-run alike.

* ``train_step``   — Eq. 4 loss + AdamW update (train_4k);
* ``prefill_step`` — one full bidirectional forward + fused confidence
                     scoring, i.e. step 0 of the sampler (prefill_32k);
* ``serve_step``   — ONE new token against a frozen KV/recurrent state of
                     the contract length + confidence scoring
                     (decode_32k / long_500k).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.confidence import score_logits, score_logits_sharded
from repro.models.layers import lm_head
from repro.models.model import decode_step, forward
from repro.training.trainer import make_train_step


def extra_input_names(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.encdec is None:
        return ()
    if cfg.encdec.frontend == "audio_stub":
        return ("enc_embeds",)
    if cfg.encdec.frontend == "vision_stub":
        return ("patch_embeds",)
    return ()


def make_steps(cfg: ModelConfig, tcfg: TrainConfig = None,
               opts: frozenset = frozenset()) -> Dict[str, Callable]:
    tcfg = tcfg or TrainConfig()
    extras = extra_input_names(cfg)
    micro = 1
    for o in opts:
        if o.startswith("microbatch"):
            micro = int(o[len("microbatch"):] or 1)
    train_step = make_train_step(cfg, tcfg, extra_inputs=extras,
                                 bf16_params="bf16_gather" in opts,
                                 microbatch=micro)

    def prefill_step(params, batch):
        """Full forward + confidence scoring over VOCAB-SHARDED logits.

        The logits stay sharded on the vocab axis (per-device slice
        ~V/16) and the four scores are computed with reduction-only ops
        that GSPMD partitions — no full-vocab gather ever happens.  This
        is the jnp realization of the fused Pallas confidence kernel's
        semantics (one streaming pass, four scalars out).
        """
        kw = {k: batch[k] for k in extras}
        hidden, _ = forward(params, batch["tokens"], cfg, return_hidden=True,
                            **kw)
        logits = lm_head(params["embed"], hidden, cfg, vocab_sharded=True)
        return score_logits_sharded(logits)

    def serve_step(params, token, position, state):
        logits, new_state = decode_step(params, token, position, state, cfg)
        return score_logits(logits), new_state

    return {"train": train_step, "prefill": prefill_step,
            "serve": serve_step}
