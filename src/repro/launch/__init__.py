"""Launchers: production meshes, the multi-pod dry-run, train/serve CLIs.

``dryrun`` must be executed as a script/module (it sets XLA_FLAGS before
importing jax); do not import it from library code.
"""
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS, make_host_mesh,
                               make_production_mesh)

__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS", "make_host_mesh",
           "make_production_mesh"]
