import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first initialization).  512 host-platform placeholder devices let
# ``jax.make_mesh`` build the production meshes; nothing is ever allocated —
# the dry-run lowers and compiles against ShapeDtypeStruct stand-ins only.
"""Multi-pod dry-run: prove every (architecture × input-shape × mesh)
combination lowers, compiles, fits — and report its roofline terms.

Per combination, THREE compiles:
  1. the FULL config — the lowering proof + memory_analysis (buffer sizes
     are exact regardless of loop structure);
  2./3. layer-reduced variants (L₀ and L₀+1 layers) — XLA's cost_analysis
     counts a scanned layer body once, not × trip count (verified), so the
     true per-step cost is extrapolated:
         cost(L) = cost(L₀) + (L − L₀)·(cost(L₀+1) − cost(L₀)).

Usage:
    python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
    python -m repro.launch.dryrun --all                  # every combo, 16×16
    python -m repro.launch.dryrun --all --multi-pod      # + (2,16,16)
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, TrainConfig, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, measure, model_flops_per_step
from repro.launch.specs import SHAPES, input_specs, shape_admissible
from repro.launch.steps import make_steps
from repro.parallel.sharding import to_named


def _compile(cfg, shape_name: str, mesh, opts: frozenset = frozenset()):
    from repro.parallel.ctx import activation_mesh
    bundle = input_specs(cfg, shape_name, mesh, opts=opts)
    step_fn = make_steps(cfg, TrainConfig(), opts=opts)[bundle.kind]
    in_shardings = to_named(mesh, bundle.in_specs)
    out_shardings = (to_named(mesh, bundle.out_specs)
                     if bundle.out_specs is not None else None)
    # serve donates the decode state (32k/500k cache updated in place);
    # train donates params + optimizer state (the AdamW update is in-place)
    donate = (3,) if bundle.kind == "serve" else \
        (0, 1) if bundle.kind == "train" else ()
    jitted = jax.jit(step_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings, donate_argnums=donate)
    with activation_mesh(mesh, seq_shard=(bundle.kind != "serve"),
                         local_moe="global_moe" not in opts,
                         seq_attn="seq_attn" in opts,
                         xgather="xgather" in opts):
        return jitted.lower(*bundle.args).compile()


def _reduced(cfg, n_layers: int):
    # unroll=True: cost_analysis counts every (unrolled) layer, so the
    # L0 -> L0+1 delta is the true per-layer cost
    over = {"num_layers": n_layers, "unroll": True}
    if cfg.encdec is not None and cfg.encdec.encoder_layers:
        over["encdec"] = dataclasses.replace(cfg.encdec,
                                             encoder_layers=n_layers)
    return dataclasses.replace(cfg, **over)


def dryrun(arch: str, shape_name: str, multi_pod: bool = False,
           verbose: bool = True, skip_full: bool = False,
           skip_roofline: bool = False,
           opts: frozenset = frozenset()) -> Roofline:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    kind, seq, batch = SHAPES[shape_name]

    # 1) full-config compile: the lowering proof + memory analysis.
    # NOTE (serve shapes): the CPU host backend legalizes bf16 dot operands
    # to f32, materializing f32 copies of the KV cache that a real TPU
    # (native bf16 MXU) never allocates — decode temp numbers are therefore
    # a ~2-3x overestimate; the honest per-device cache size is
    # argument_size (see EXPERIMENTS.md §Dry-run).
    peak = None
    args_bytes = None
    t_full = 0.0
    if not skip_full:
        t0 = time.perf_counter()
        compiled_full = _compile(cfg, shape_name, mesh, opts)
        t_full = time.perf_counter() - t0
        ma = compiled_full.memory_analysis()
        peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        args_bytes = ma.argument_size_in_bytes
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] "
                  f"full compile {t_full:.1f}s")
            print(f"  memory_analysis: {ma}")
        del compiled_full

    if skip_roofline:
        return Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                        chips=chips, flops=0.0, bytes_accessed=0.0,
                        coll_bytes=0.0, peak_memory=peak,
                        args_bytes=args_bytes,
                        model_flops=model_flops_per_step(cfg, kind, seq,
                                                         batch))

    # 2) per-layer extrapolation (scan bodies are counted once by XLA)
    l0 = (cfg.moe.first_k_dense + 1) if cfg.is_moe else 1
    l0 = max(l0, 1)
    t0 = time.perf_counter()
    m_lo = measure(_compile(_reduced(cfg, l0), shape_name, mesh, opts))
    m_hi = measure(_compile(_reduced(cfg, l0 + 1), shape_name, mesh, opts))
    t_extr = time.perf_counter() - t0
    n_extra = cfg.num_layers - l0
    # the microbatch accumulation loop is also a scan counted once: scale
    # terms by the microbatch factor so per-step costs stay comparable
    micro = 1
    for o in opts:
        if o.startswith("microbatch"):
            micro = int(o[len("microbatch"):] or 1)
    # per-layer deltas clamped at 0: XLA optimization variance between the
    # two compiles can otherwise produce (meaningless) negative terms
    def ext(lo, hi):
        return (lo + n_extra * max(0.0, hi - lo)) * micro

    flops = ext(m_lo[0], m_hi[0])
    byts = ext(m_lo[1], m_hi[1])
    coll = ext(m_lo[2], m_hi[2])
    breakdown = {k: int(ext(m_lo[3].get(k, 0), m_hi[3].get(k, 0)))
                 for k in set(m_lo[3]) | set(m_hi[3])}

    roof = Roofline(arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
                    flops=flops, bytes_accessed=byts, coll_bytes=coll,
                    coll_breakdown=breakdown,
                    model_flops=model_flops_per_step(cfg, kind, seq, batch),
                    peak_memory=peak, args_bytes=args_bytes)
    if verbose:
        print(f"  layer-extrapolated (L0={l0}, {t_extr:.1f}s): "
              f"flops={flops:.3e} bytes={byts:.3e} coll={coll:.3e}")
        print(f"  roofline: compute {roof.t_compute * 1e3:.2f} ms | "
              f"memory {roof.t_memory * 1e3:.2f} ms | "
              f"collective {roof.t_collective * 1e3:.2f} ms "
              f"-> {roof.bottleneck}-bound | useful {roof.useful_ratio:.3f}"
              + (f" | peak {peak / 2**30:.2f} GiB/dev" if peak else ""))
    return roof


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all assigned)")
    ap.add_argument("--shape", default=None,
                    help="input shape id (default: all four)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the (2,16,16) 512-chip mesh")
    ap.add_argument("--all", action="store_true",
                    help="run every admissible (arch × shape)")
    ap.add_argument("--skip-full", action="store_true",
                    help="skip the full-config compile (roofline terms only)")
    ap.add_argument("--no-roofline", action="store_true",
                    help="full lower+compile proof only (multi-pod pass)")
    ap.add_argument("--opt", default="",
                    help="comma list of perf knobs: bf16_gather,"
                         "infer_replicate,infer_bf16")
    ap.add_argument("--json", default=None, help="append JSONL results here")
    args = ap.parse_args()
    opts = frozenset(o for o in args.opt.split(",") if o)

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    results, failures = [], []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            if not shape_admissible(cfg, shape):
                print(f"[{arch} × {shape}] SKIP "
                      f"(full-attention arch; see DESIGN.md)")
                continue
            try:
                roof = dryrun(arch, shape, multi_pod=args.multi_pod,
                              skip_full=args.skip_full,
                              skip_roofline=args.no_roofline, opts=opts)
                results.append(roof)
            except Exception as e:   # a failure here is a sharding bug
                failures.append((arch, shape, repr(e)))
                traceback.print_exc()
    print(f"\n=== dry-run summary: {len(results)} ok, "
          f"{len(failures)} failed ===")
    for arch, shape, err in failures:
        print(f"  FAIL {arch} × {shape}: {err[:200]}")
    if args.json and results:
        with open(args.json, "a") as f:
            for r in results:
                f.write(json.dumps({
                    "arch": r.arch, "shape": r.shape, "mesh": r.mesh,
                    "chips": r.chips, "flops": r.flops,
                    "bytes": r.bytes_accessed, "coll_bytes": r.coll_bytes,
                    "coll_breakdown": r.coll_breakdown,
                    "model_flops": r.model_flops,
                    "peak_memory": r.peak_memory,
                    "args_bytes": r.args_bytes,
                    "t_compute": r.t_compute, "t_memory": r.t_memory,
                    "t_collective": r.t_collective,
                    "bottleneck": r.bottleneck,
                    "useful_ratio": r.useful_ratio,
                    "opts": sorted(opts)}) + "\n")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
