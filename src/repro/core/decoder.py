"""The first-class decoding API: ``Decoder`` + the cross-call runner cache.

The paper's whole contribution is the *strategy* (FDM / FDM-A vs. the
heuristic and dynamic baselines), so the strategy and the machinery that
drives it are first-class objects here, mirroring the
``DiffusionLLM(model, decoder, …)`` composition of the dInfer line of
work:

* ``Strategy`` (``core/strategies.py``) — carries per-decode state
  (``init_carry``), declares its own fused form, registers by name.
* ``Decoder`` (this module) — owns the semi-AR block loop for BOTH
  execution modes (plain full-sequence re-forward, and frozen-prefix
  cached decoding), the RNG threading, ``SampleStats`` accounting,
  per-block streaming callbacks, and the compiled-runner cache.

``Decoder(params_or_model_fn, cfg, dcfg)``:

* **params mode** (pass a params pytree) — the Decoder builds its own
  forwards.  Compiled runners take ``params`` as a *traced argument*, so
  model weights are never baked into an executable: new params with the
  same structure reuse the compilation, and dropping the last user
  reference to the params actually frees everything.
* **model_fn mode** (pass a callable ``tokens -> logits``) — for
  callers that already own a (jitted) forward.  The runner holds the
  callable only through a weakref, dereferenced at trace time.

KV caching is a first-class axis of the execution surface
(``DecodeConfig.cache_policy`` ∈ ``{none, prefix, dual}``, DESIGN.md
"The KV cache"): ``prefix`` freezes the prompt's K/V and keeps the whole
generation region live; ``dual`` (Fast-dLLM-style) additionally freezes
committed blocks and the masked suffix, recomputing only the active
block.  Both ride the SAME fused drivers as the plain path — the
fixed-shape cache is a traced runner argument threaded through the
``lax.scan`` carry, so one executable per strategy × shape × policy
serves every prompt length, and all three drivers (host loop, per-block
fused, whole-request fused) decode bit-identically per policy.  The
legacy ``generate_cached`` shrinking-window path is subsumed by
``cache_policy="prefix"`` (see the DESIGN.md migration note).

The runner cache (``RunnerCache``) is module-global and *weak*: entries
are keyed on the identity of the params leaves (or the model_fn) and
evicted by a ``weakref.finalize`` when the keying object is collected.
This replaces two seed-era idioms with one mechanism: the seed's
``lru_cache`` over runners (which pinned model_fns/params forever — a
leak for long-lived multi-model serving) and its per-call re-jit of the
cached-path forwards (params pytrees don't hash, so the seed simply
recompiled every call).  Repeat decodes with the same weights now
compile nothing, in every policy; ``decode_cache_info()`` exposes
hit/miss/trace counters so tests and benchmarks can assert exactly that.

Streaming: ``generate`` accepts
``on_block_committed(block_index, lo, hi, x)``, fired after each block
commits (the natural streaming grain of blockwise diffusion decoding —
tokens inside a block finalize together).  ``x`` is the live device
canvas; don't block in the callback.
"""
from __future__ import annotations

import contextlib
import time
import weakref
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.loop import (carry_unwindow, carry_window,
                             drive_block, drive_cached_block,
                             drive_request, drive_request_cached,
                             window_geometry)
from repro.core.masking import fully_masked
from repro.core.strategies import Strategy, resolve_strategy
from repro.core.tracebuffer import DecodeTrace, TracingStrategy, tracing


@dataclass
class SampleStats:
    steps: int = 0
    forward_equivalents: int = 0   # batched-forward count (K-search = K)
    wall_time: float = 0.0
    tokens_generated: int = 0
    phase_counts: Dict[str, float] = field(default_factory=dict)
    # per-phase step counts (FDM-A: explore/accel/local_only/balance),
    # accumulated on device in the strategy carry; ints from Decoder
    # (one flag per batch row per step), per-example averages — possibly
    # fractional, still summing to `steps` — from ServingEngine
    revocations: float = 0.0
    # committed tokens un-committed (re-masked) by a revoking strategy
    # (wino_r); whole-batch total from Decoder, pro-rated per request by
    # ServingEngine.  Each revocation is extra work the step/forward
    # counters already include (the re-decode runs as ordinary steps).
    skipped_forwards: float = 0.0
    # model calls AVOIDED by an extrapolating strategy: steps that
    # committed straight from the carry.  Plain path invariant:
    # steps == forward_equivalents + skipped_forwards (the cached path
    # pro-rates forwards by window size but counts skips raw).
    trace: Optional[DecodeTrace] = None
    # per-step telemetry (dcfg.trace=True only): commit order/confidence,
    # revocations, skips, phases — core/tracebuffer.py.

    @property
    def tps(self) -> float:
        return self.tokens_generated / max(self.wall_time, 1e-9)

    @property
    def tokens_per_forward(self) -> float:
        return self.tokens_generated / max(self.forward_equivalents, 1)

    def as_dict(self) -> Dict[str, Any]:
        """The one stable wire/summary form of a decode's stats — the
        HTTP terminal event, ``ServingEngine.summary()``, and the
        benchmarks all read THIS instead of hand-picking fields (they
        had drifted).  JSON-safe, unrounded — aggregators sum these, so
        precision loss here would show up as drift in their invariants;
        the trace object stays off the wire (it has its own endpoint)."""
        return {
            "steps": int(self.steps),
            "forward_equivalents": float(self.forward_equivalents),
            "wall_time_s": float(self.wall_time),
            "tokens_generated": int(self.tokens_generated),
            "tps": float(self.tps),
            "tokens_per_forward": float(self.tokens_per_forward),
            "revocations": float(self.revocations),
            "skipped_forwards": float(self.skipped_forwards),
            "phase_counts": dict(self.phase_counts),
        }


class BlockEvent(NamedTuple):
    """One committed semi-AR block, as yielded by ``Decoder.generate_blocks``
    (and delivered to ``on_block_committed`` callbacks as positional args).
    ``x`` is the live canvas — the whole ``(B, L)`` token array with the
    block's columns ``lo:hi`` finalized."""
    block: int
    lo: int
    hi: int
    x: Any


class CacheInfo(NamedTuple):
    entries: int     # distinct params/model_fn identities alive
    runners: int     # compiled-runner callables across all entries
    hits: int        # runner lookups served without building
    misses: int      # runner builds (new jit wrapper created)
    traces: int      # actual XLA traces of cached runners (recompiles)


class RunnerCache:
    """Weak, identity-keyed cache of compiled decode runners.

    Key = the identity of the model weights (every params leaf) or of the
    model_fn callable; ``weakref.finalize`` anchors on **every** keying
    object evict the whole entry as soon as *any* of them is collected
    (first finalizer wins).  Anchoring only the first leaf would be a
    correctness bug, not just a leak: the key is a tuple of ``id()``s,
    which are only unique while the objects are alive — if a non-first
    leaf dies (e.g. a partial weight swap) while leaf 0 survives, a
    recycled id could silently collide into a false cache hit.  Values
    never reference the keying objects strongly (params are runner
    *arguments*; model_fns are weakref'd), so eviction genuinely fires —
    unlike an ``lru_cache``, nothing here can pin model weights.
    """

    def __init__(self):
        self._entries: Dict[tuple, Dict[tuple, Any]] = {}
        self._finalizers: Dict[tuple, list] = {}
        self.hits = 0
        self.misses = 0
        self.traces = 0

    @staticmethod
    def key_for(model) -> Tuple[tuple, tuple]:
        """(cache key, weakref anchors) for a params pytree or callable."""
        if callable(model):
            return ("fn", id(model)), (model,)
        leaves = jax.tree.leaves(model)
        if not leaves:
            raise ValueError("params pytree has no array leaves")
        return ("params", tuple(map(id, leaves))), tuple(leaves)

    def get(self, key: tuple, anchors: tuple, subkey: tuple,
            builder: Callable[[], Any]) -> Any:
        entry = self._entries.get(key)
        if entry is None:
            entry = self._entries[key] = {}
            self._finalizers[key] = [
                weakref.finalize(a, self._evict, key) for a in anchors]
        runner = entry.get(subkey)
        if runner is None:
            self.misses += 1
            runner = entry[subkey] = builder()
        else:
            self.hits += 1
        return runner

    def _evict(self, key: tuple) -> None:
        self._entries.pop(key, None)
        # detach the surviving finalizers: a stale one firing later could
        # evict a NEW entry that reused the (recycled-id) key tuple
        for fin in self._finalizers.pop(key, ()):
            fin.detach()

    def note_trace(self) -> None:
        """Called from inside runner bodies: the side effect executes only
        while jax is tracing, so this counts real (re)compilations."""
        self.traces += 1

    def info(self) -> CacheInfo:
        return CacheInfo(entries=len(self._entries),
                         runners=sum(len(e) for e in self._entries.values()),
                         hits=self.hits, misses=self.misses,
                         traces=self.traces)

    def reset_stats(self) -> None:
        """Zero the hit/miss/trace counters WITHOUT dropping any cached
        runner — compiled work survives, only the accounting restarts."""
        self.hits = self.misses = self.traces = 0

    def clear(self) -> None:
        for fins in list(self._finalizers.values()):
            for fin in fins:
                fin.detach()
        self._entries.clear()
        self._finalizers.clear()
        self.reset_stats()


_GLOBAL_CACHE = RunnerCache()

# conditioning inputs forward() accepts; generate(**extras) validates
# against this so a typo'd keyword fails at the call site instead of
# surfacing as an opaque trace error (or a bogus model input)
_CONDITIONING_KEYS = frozenset({"enc_embeds", "patch_embeds"})


def decode_cache_info() -> CacheInfo:
    """Counters of the process-wide Decoder runner cache."""
    return _GLOBAL_CACHE.info()


def clear_decode_cache() -> None:
    _GLOBAL_CACHE.clear()


def reset_decode_cache_stats() -> None:
    """Zero the process-wide cache's hit/miss/trace counters, keeping its
    compiled runners.  Compile-count assertions (`traces == N`) should
    call this — or use ``decode_cache_scope`` — first, so they measure
    their own work instead of whatever ran earlier in the process (under
    CI test ordering the module-global counters are otherwise a flake
    source)."""
    _GLOBAL_CACHE.reset_stats()


@contextlib.contextmanager
def decode_cache_scope(cache: Optional[RunnerCache] = None):
    """Swap a fresh (or caller-supplied) ``RunnerCache`` in as the
    process-wide cache for the duration of the ``with`` block.

    Decoders constructed inside the scope — including the ones the
    ServingEngine builds internally — resolve
    against the scoped cache, so its counters see exactly the scope's
    work and its entries drop with the scope (previously cached runners
    reappear after exit, untouched).  Yields the scoped cache.
    """
    global _GLOBAL_CACHE
    prev = _GLOBAL_CACHE
    _GLOBAL_CACHE = cache if cache is not None else RunnerCache()
    try:
        yield _GLOBAL_CACHE
    finally:
        _GLOBAL_CACHE = prev


def _tiling_forward(params, cfg: ModelConfig, extras: Dict[str, Any]):
    """tokens (B', L) -> logits, tiling conditioning inputs (enc_embeds /
    patch_embeds) candidate-major to match a K·B folded batch."""
    from repro.models.model import forward

    def mf(t):
        kw = {}
        for k, v in extras.items():
            reps = t.shape[0] // v.shape[0]
            kw[k] = jnp.tile(v, (reps,) + (1,) * (v.ndim - 1)) \
                if reps > 1 else v
        return forward(params, t, cfg, **kw)[0]

    return mf


def _tile_state(st, reps: int):
    """Replicate a DecodeState candidate-major along its batch axis."""
    if reps == 1:
        return st
    from repro.models.model import DecodeState
    ls = jax.tree.map(
        lambda a: jnp.tile(a, (1, reps) + (1,) * (a.ndim - 2))
        if a.ndim >= 2 else a, st.layer_states)
    eo = None if st.enc_out is None else jnp.tile(st.enc_out, (reps, 1, 1))
    return DecodeState(layer_states=ls, enc_out=eo)


def _cached_model_fn(params, cfg: ModelConfig, batch: int) -> Callable:
    """``(x_win, win_lo, state) -> logits`` for the cached drivers,
    tiling the cache candidate-major when a foreseeing strategy folds
    K candidates into the batch axis."""
    from repro.models.model import forward_cached

    def cf(w, win_lo, st):
        return forward_cached(params, w, win_lo,
                              _tile_state(st, w.shape[0] // batch), cfg)

    return cf


def validate_cache_policy(cfg: ModelConfig, dcfg: DecodeConfig) -> None:
    """Boundary validation for the cache-policy axis: raise ``ValueError``
    if ``cfg`` cannot serve ``dcfg.cache_policy`` (callers at trust
    boundaries — ``ServingEngine.submit`` — map this to a 400).

    The fixed-shape block cache scatters fresh window K/V into full-length
    buffers; recurrent state (ssm/hybrid) is a running reduction and has
    no per-position rows to scatter into, so those archs only support
    ``cache_policy="none"``.
    """
    if dcfg.cache_policy == "none":
        return
    if cfg.arch_type in ("ssm", "hybrid") or cfg.attention == "none":
        raise ValueError(
            f"cache_policy={dcfg.cache_policy!r} requires an "
            f"attention-backed architecture (gqa/mla); "
            f"{cfg.name!r} is arch_type={cfg.arch_type!r} with "
            f"attention={cfg.attention!r} — recurrent state cannot ride "
            f"the fixed-shape block cache")


class Decoder:
    """One composable decode stack: block orchestration for any registered
    ``Strategy``, plain or cached execution, shared compiled-runner cache.

    See the module docstring for the two construction modes.  Typical use::

        dec = Decoder(params, cfg, dcfg)
        tokens, stats = dec.generate(rng, prompt)

        # KV-cached decoding is the same call under a different policy:
        dcfg2 = dataclasses.replace(dcfg, cache_policy="prefix")
        tokens, stats = Decoder(params, cfg, dcfg2).generate(rng, prompt)

    ``Decoder`` objects are cheap: compiled runners live in the shared
    module-level cache keyed on the weights' identity, so constructing a
    fresh ``Decoder`` per request (as the ServingEngine does under
    per-request overrides) still compiles nothing after the first decode.
    """

    def __init__(self, model, cfg: ModelConfig, dcfg: DecodeConfig, *,
                 cache: Optional[RunnerCache] = None):
        self.cfg = cfg
        self.dcfg = dcfg
        self._cache = _GLOBAL_CACHE if cache is None else cache
        if callable(model):
            self._model_fn, self._params = model, None
        else:
            self._model_fn, self._params = None, model
        self._key, self._anchor = RunnerCache.key_for(model)
        # optional telemetry hook ``(block_index, t_start_s, t_end_s)``
        # fired around each KV-cache refresh on the blockwise path (the
        # serving layer turns these into trace spans); None = free
        self.on_cache_refresh: Optional[Callable] = None

    # -- geometry ----------------------------------------------------------
    def _geometry(self) -> Tuple[int, int, int, np.ndarray]:
        """Block layout + the per-block commit-width schedules.

        Returns ``(gen, block_size, num_blocks, schedules)`` where
        ``schedules`` is ``(num_blocks, S)`` int32: row ``b``, entry ``i``
        is the nominal commit width handed to the strategy at step ``i``
        of block ``b`` (the index clamps to the row end in the drivers).

        ``dcfg.steps`` is distributed EXACTLY whenever it is feasible
        (``num_blocks ≤ steps ≤ gen_length``): the per-block step budgets
        spread ``steps`` across blocks with the remainder going to the
        leading blocks, and each block's widths spread ``block_size``
        tokens across its budget likewise (the seed floored both
        divisions, so ``steps=10, num_blocks=4`` quietly ran 8 steps).
        When both divisions are exact this degenerates to the seed's
        constant ``n_per_step`` — bit-identical decodes.  A budget below
        ``num_blocks`` is infeasible (each block takes ≥ 1 step) and
        raises; a budget above ``gen_length`` is a CAP, not a target —
        each step commits ≥ 1 token, so a block's schedule tail is
        unreachable and the decode runs ``gen_length`` steps.

        Net-committed accounting: commit schedules may UN-commit.  A
        revoking strategy (``wino_r``) re-masks tokens, so a block's net
        progress per step can fall below the scheduled width and the
        block legitimately overruns its schedule row.  Rows are therefore
        padded with their FINAL width — never zero — so overrun steps
        (reached only by revocation, since non-revoking width-respecting
        strategies' widths sum exactly to ``block_size``) keep committing
        and the block still terminates inside the ``block_size·4`` safety
        cap; width-ignoring strategies never read ``n`` at all.
        """
        dcfg = self.dcfg
        gen, bs = dcfg.gen_length, dcfg.block_size
        assert gen % bs == 0, (gen, bs)
        num_blocks = gen // bs
        if dcfg.steps < num_blocks:
            raise ValueError(
                f"DecodeConfig.steps={dcfg.steps} is infeasible: semi-AR "
                f"decoding runs at least one step per block and "
                f"gen_length={gen} / block_size={bs} gives {num_blocks} "
                f"blocks — raise steps or shrink the block count")
        base, rem = divmod(dcfg.steps, num_blocks)
        budgets = [base + (1 if b < rem else 0) for b in range(num_blocks)]
        sched = np.zeros((num_blocks, max(budgets)), np.int32)
        for b, spb in enumerate(budgets):
            w, wr = divmod(bs, spb)
            widths = [w + 1] * wr + [w] * (spb - wr)
            # pad with the final width (see docstring: revocation overrun)
            sched[b] = widths + [widths[-1]] * (sched.shape[1] - spb)
        return gen, bs, num_blocks, sched

    # -- runner construction (all cached cross-call) -----------------------
    def _plain_runner(self, strat: Strategy,
                      extras: Optional[Dict[str, Any]] = None) -> Callable:
        """Per-block fused runner with uniform signature
        ``run(x, rng, lo, sched, steps, fwd, carry) -> 5-tuple``; ``lo``
        (block start) and ``sched`` (per-step commit widths) are traced,
        so all blocks (and all later decodes with the same weights) share
        one executable per shape."""
        cfg, dcfg, cache = self.cfg, self.dcfg, self._cache
        bs = dcfg.block_size
        subkey = ("block", strat, cfg, dcfg)
        if self._model_fn is not None:
            if extras:
                raise ValueError("extras require a params-mode Decoder "
                                 "(a model_fn already owns its "
                                 "conditioning)")
            mf_ref = weakref.ref(self._model_fn)

            def build():
                @jax.jit
                def run(x, rng, lo, sched, steps, fwd, carry):
                    cache.note_trace()
                    mf = mf_ref()       # trace-time only; caller holds it
                    if mf is None:
                        raise RuntimeError("model_fn was garbage-collected")
                    pos = jnp.arange(x.shape[1])
                    in_block = (pos >= lo) & (pos < lo + bs)
                    return drive_block(strat, mf, cfg, dcfg, sched,
                                       x, rng, in_block, steps, fwd, carry)
                return run

            return cache.get(self._key, self._anchor, subkey, build)

        def build():
            @jax.jit
            def run(params, ex, x, rng, lo, sched, steps, fwd, carry):
                cache.note_trace()
                pos = jnp.arange(x.shape[1])
                in_block = (pos >= lo) & (pos < lo + bs)
                mf = _tiling_forward(params, cfg, ex)
                return drive_block(strat, mf, cfg, dcfg, sched,
                                   x, rng, in_block, steps, fwd, carry)
            return run

        raw = self._cache.get(self._key, self._anchor, subkey, build)
        params, ex = self._params, dict(extras or {})
        return lambda x, rng, lo, sched, steps, fwd, carry: \
            raw(params, ex, x, rng, lo, sched, steps, fwd, carry)

    def _request_runner(self, strat: Strategy, stream: bool,
                        extras: Optional[Dict[str, Any]] = None
                        ) -> Tuple[Callable, Optional[dict]]:
        """Whole-request fused runner: ONE compiled dispatch drives every
        block (``core/loop.py:drive_request``).  Signature
        ``run(x, rng, block_los, schedules, steps, fwd, carry)`` with the
        block offsets and commit schedules traced, so one executable per
        strategy × shape serves every prompt length / step budget of that
        shape.

        Streaming: compiled programs outlive any single ``generate`` call,
        so the per-call ``on_block_committed`` cannot be baked in.  The
        streaming variant (``stream=True``, its own cache subkey) routes
        an ordered ``io_callback`` through a mutable holder dict owned by
        the cached runner; ``generate`` installs the live callback before
        dispatch and clears it after the canvas syncs.  Returns
        ``(run, holder)`` — ``holder`` is ``None`` for the plain variant.
        """
        cfg, dcfg, cache = self.cfg, self.dcfg, self._cache
        subkey = ("request", strat, cfg, dcfg, bool(stream))

        def make_emit(holder):
            def emit(blk, lo, hi, canvas):
                cb = holder.get("cb")
                if cb is not None:
                    cb(int(blk), int(lo), int(hi), canvas)
            return emit

        if self._model_fn is not None:
            if extras:
                raise ValueError("extras require a params-mode Decoder "
                                 "(a model_fn already owns its "
                                 "conditioning)")
            mf_ref = weakref.ref(self._model_fn)

            def build():
                holder = {"cb": None} if stream else None
                emit = make_emit(holder) if stream else None

                @jax.jit
                def run(x, rng, los, scheds, steps, fwd, carry):
                    cache.note_trace()
                    mf = mf_ref()
                    if mf is None:
                        raise RuntimeError("model_fn was garbage-collected")
                    return drive_request(strat, mf, cfg, dcfg, x, rng,
                                         los, scheds, steps, fwd, carry,
                                         emit=emit)
                return run, holder

            return cache.get(self._key, self._anchor, subkey, build)

        def build():
            holder = {"cb": None} if stream else None
            emit = make_emit(holder) if stream else None

            @jax.jit
            def run(params, ex, x, rng, los, scheds, steps, fwd, carry):
                cache.note_trace()
                mf = _tiling_forward(params, cfg, ex)
                return drive_request(strat, mf, cfg, dcfg, x, rng,
                                     los, scheds, steps, fwd, carry,
                                     emit=emit)
            return run, holder

        raw, holder = self._cache.get(self._key, self._anchor, subkey,
                                      build)
        params, ex = self._params, dict(extras or {})
        return (lambda x, rng, los, scheds, steps, fwd, carry:
                raw(params, ex, x, rng, los, scheds, steps, fwd, carry),
                holder)

    def _host_model_fn(self, extras: Optional[Dict[str, Any]]) -> Callable:
        """tokens -> logits for the legacy host step loop."""
        if self._model_fn is not None:
            if extras:
                raise ValueError("extras require a params-mode Decoder")
            return self._model_fn
        cfg, cache = self.cfg, self._cache

        def build():
            @jax.jit
            def fwd(params, ex, t):
                cache.note_trace()
                return _tiling_forward(params, cfg, ex)(t)
            return fwd

        raw = cache.get(self._key, self._anchor, ("fwd", cfg), build)
        params, ex = self._params, dict(extras or {})
        return lambda t: raw(params, ex, t)

    def _refresh_runner(self) -> Callable:
        """Jitted cache capture ``refresh(canvas) -> DecodeState`` — the
        prefill and block-boundary refresh op of the cached path (one
        full forward over the canvas, LM head skipped).  Strategy- and
        dcfg-independent: every policy and strategy on the same weights
        shares one compilation per canvas shape."""
        cfg, cache = self.cfg, self._cache

        def build():
            from repro.models.model import capture_cache

            @jax.jit
            def refresh(params, canvas):
                cache.note_trace()
                return capture_cache(params, canvas, cfg)
            return refresh

        raw = cache.get(self._key, self._anchor, ("refresh", cfg), build)
        params = self._params
        return lambda canvas: raw(params, canvas)

    def _cached_forward_fn(self) -> Callable:
        """Jitted windowed forward ``(x_win, win_lo, state) -> logits``
        for the host step loop of the cached path."""
        cfg, cache = self.cfg, self._cache

        def build():
            from repro.models.model import forward_cached

            @jax.jit
            def cfwd(params, w, win_lo, st):
                cache.note_trace()
                return forward_cached(params, w, win_lo, st, cfg)
            return cfwd

        raw = cache.get(self._key, self._anchor, ("cached_fwd", cfg),
                        build)
        params = self._params
        return lambda w, win_lo, st: raw(params, w, win_lo, st)

    def _cached_block_runner(self, strat: Strategy) -> Callable:
        """Per-block fused runner for the cached path: signature
        ``run(x, rng, lo, sched, steps, fwd, carry, state)`` over the FULL
        canvas — window slicing happens inside the trace
        (``drive_cached_block``), with ``lo`` traced, so one executable
        per strategy × shape × policy serves every block of every
        request.  ``state`` is the traced fixed-shape cache from
        ``_refresh_runner`` (never a baked const — ANA103)."""
        cfg, dcfg, cache = self.cfg, self.dcfg, self._cache
        subkey = ("cached_block", strat, cfg, dcfg)

        def build():
            @jax.jit
            def run(params, x, rng, lo, sched, steps, fwd, carry, state):
                cache.note_trace()
                cf = _cached_model_fn(params, cfg, x.shape[0])
                return drive_cached_block(strat, cf, cfg, dcfg, x, rng,
                                          lo, sched, steps, fwd, carry,
                                          state)
            return run

        raw = cache.get(self._key, self._anchor, subkey, build)
        params = self._params
        return lambda x, rng, lo, sched, steps, fwd, carry, state: \
            raw(params, x, rng, lo, sched, steps, fwd, carry, state)

    def _cached_request_runner(self, strat: Strategy, stream: bool
                               ) -> Tuple[Callable, Optional[dict]]:
        """Whole-request fused runner for the cached path
        (``drive_request_cached``): prefill, every block's windowed
        ``while_loop`` AND the block-boundary cache refreshes run as one
        compiled dispatch.  Same signature and streaming-holder contract
        as ``_request_runner``."""
        cfg, dcfg, cache = self.cfg, self.dcfg, self._cache
        subkey = ("request_cached", strat, cfg, dcfg, bool(stream))

        def make_emit(holder):
            def emit(blk, lo, hi, canvas):
                cb = holder.get("cb")
                if cb is not None:
                    cb(int(blk), int(lo), int(hi), canvas)
            return emit

        def build():
            holder = {"cb": None} if stream else None
            emit = make_emit(holder) if stream else None

            @jax.jit
            def run(params, x, rng, los, scheds, steps, fwd, carry):
                cache.note_trace()
                from repro.models.model import capture_cache
                cf = _cached_model_fn(params, cfg, x.shape[0])
                return drive_request_cached(
                    strat, cf, lambda cv: capture_cache(params, cv, cfg),
                    cfg, dcfg, x, rng, los, scheds, steps, fwd, carry,
                    emit=emit)
            return run, holder

        raw, holder = self._cache.get(self._key, self._anchor, subkey,
                                      build)
        params = self._params
        return (lambda x, rng, los, scheds, steps, fwd, carry:
                raw(params, x, rng, los, scheds, steps, fwd, carry),
                holder)

    # -- decoding ----------------------------------------------------------
    def generate(self, rng, prompt: jnp.ndarray,
                 strategy: Optional[str] = None,
                 on_block_committed: Optional[Callable] = None,
                 **extras) -> Tuple[jnp.ndarray, SampleStats]:
        """Decode ``gen_length`` tokens after ``prompt`` (B, Lp).
        Returns (tokens (B, Lp+gen), SampleStats).

        ``strategy``: registered name or ``Strategy``; defaults to
        ``dcfg.strategy``.  ``extras`` (params mode only): conditioning
        arrays forwarded to the model (enc_embeds / patch_embeds).
        ``on_block_committed(block_index, lo, hi, x)`` fires after each
        committed block.

        ``dcfg.cache_policy`` selects the execution mode: ``none`` runs a
        full re-forward per step; ``prefix``/``dual`` decode windowed
        steps against the fixed-shape KV cache (params mode only —
        DESIGN.md "The KV cache").  Per policy, three drivers decode
        bit-identical tokens/steps (parity-tested for every registered
        strategy):

        * ``fused_loop ∧ fused_blocks`` (default) — the whole request is
          ONE compiled dispatch (``drive_request`` /
          ``drive_request_cached``, which folds the prefill and every
          block-boundary cache refresh into the same dispatch);
          streaming callbacks fire via ordered ``io_callback``.
        * ``fused_loop ∧ ¬fused_blocks`` — one dispatch per block
          (``drive_block`` / ``drive_cached_block``), callbacks from
          host between blocks.
        * ``¬fused_loop`` — the legacy host step loop, for debugging.

        The two per-block drivers are served by ``generate_blocks`` (the
        block-boundary yield point); this method drains it, forwarding
        events to ``on_block_committed``.
        """
        self._check_extras(extras)
        cfg, dcfg = self.cfg, self.dcfg
        strat = resolve_strategy(strategy or dcfg.strategy)
        if dcfg.trace:
            # the memoized wrapper keeps strategy identity stable across
            # calls, so traced decodes get their own cached runners
            # (per the dcfg-keyed subkeys) without recompiling per call
            # — and trace=off decodes never see the wrapper at all
            strat = tracing(strat)
        cached = dcfg.cache_policy != "none"
        if cached:
            self._check_cached(extras)
        fused = dcfg.fused_loop and strat.supports_fused
        if not (fused and dcfg.fused_blocks):
            blocks = self.generate_blocks(rng, prompt, strategy=strat,
                                          **extras)
            while True:
                try:
                    ev = next(blocks)
                except StopIteration as fin:
                    return fin.value
                if on_block_committed is not None:
                    on_block_committed(ev.block, ev.lo, ev.hi, ev.x)
        b, lp = prompt.shape
        gen, bs, num_blocks, sched = self._geometry()
        x = fully_masked(cfg, prompt, gen)
        carry = strat.init_carry_shaped(cfg, dcfg, b, lp + gen)
        stats = SampleStats(tokens_generated=b * gen)
        t0 = time.perf_counter()

        stream = on_block_committed is not None
        run, holder = self._cached_request_runner(strat, stream) if cached \
            else self._request_runner(strat, stream, extras)
        if holder is not None:
            # the holder is shared through the runner cache by every
            # Decoder on the same weights: refuse to clobber a live
            # callback (concurrent/re-entrant streaming decode) —
            # silent event misdelivery would be far worse
            if holder["cb"] is not None:
                raise RuntimeError(
                    "concurrent streaming decodes with the same "
                    "weights and DecodeConfig are not supported: "
                    "another generate(on_block_committed=...) is "
                    "still in flight for this compiled runner")
            holder["cb"] = on_block_committed
        try:
            los = lp + bs * jnp.arange(num_blocks, dtype=jnp.int32)
            x, rng, steps, fwd, carry = run(
                x, rng, los, jnp.asarray(sched),
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32),
                carry)
            # one sync for the whole decode
            x.block_until_ready()
        finally:
            if holder is not None:
                # output readiness does NOT imply host-callback
                # completion on async backends: drain the ordered
                # io_callbacks before releasing the holder, or the
                # tail events would be dropped (or delivered to the
                # next streaming decode's callback)
                jax.effects_barrier()
                holder["cb"] = None
        stats.steps = int(jax.device_get(steps))
        stats.forward_equivalents = float(jax.device_get(fwd))
        if isinstance(strat, TracingStrategy):
            stats.trace = strat.extract(carry)
        self._merge_carry_stats(stats, strat, carry)
        stats.wall_time = time.perf_counter() - t0
        return x, stats

    def generate_blocks(self, rng, prompt: jnp.ndarray,
                        strategy: Optional[str] = None, **extras):
        """The block-boundary yield point: decode like ``generate`` but at
        the per-block grain, handing control back to the caller after
        every committed block.

        Returns a generator of ``BlockEvent(block, lo, hi, x)``; the
        generator's return value (``StopIteration.value``) is the same
        ``(tokens, stats)`` pair ``generate`` returns.  Between blocks the
        caller may do anything — fan events out to streams, check
        cancellation deadlines, admit new work to other queues — which is
        exactly the scheduling grain of batch-synchronous diffusion
        decoding: a running batch cannot be preempted mid-block, but
        between blocks the host is in full control.  The async serving
        scheduler (``repro.serving.scheduler``) is the primary consumer.

        Drives per-block dispatches (``fused_loop`` chooses the fused
        block runner vs. the legacy host step loop; ``fused_blocks`` does
        not apply — a single whole-request dispatch has no host boundary
        to yield at).  Decodes are bit-identical to ``generate``'s
        (three-driver parity is tested for every registered strategy).
        """
        self._check_extras(extras)
        strat = resolve_strategy(strategy or self.dcfg.strategy)
        if self.dcfg.trace:
            strat = tracing(strat)
        # geometry errors should raise HERE, not at the first next()
        geometry = self._geometry()
        return self._blocks_gen(strat, rng, prompt, geometry, extras)

    def _blocks_gen(self, strat: Strategy, rng, prompt, geometry, extras):
        cfg, dcfg = self.cfg, self.dcfg
        cached = dcfg.cache_policy != "none"
        if cached:
            self._check_cached(extras)
        b, lp = prompt.shape
        gen, bs, num_blocks, sched = geometry
        total = lp + gen
        x = fully_masked(cfg, prompt, gen)
        carry = strat.init_carry_shaped(cfg, dcfg, b, total)
        stats = SampleStats(tokens_generated=b * gen)
        t0 = time.perf_counter()
        # cached path: prefill captures the fixed-shape cache (= block 0's
        # refresh); later refreshes run from host at block boundaries.
        # Each capture is one full forward, accounted host-side so all
        # three drivers report the same forward_equivalents.
        refresh = self._refresh_runner() if cached else None
        hook = self.on_cache_refresh

        def timed_refresh(canvas, blk):
            if hook is None:
                return refresh(canvas)
            # hook installed = serving-layer tracing: the extra sync is
            # paid only then, and the blockwise caller syncs per block
            # anyway (it materializes each block's tokens on host)
            t0r = time.perf_counter()
            st = refresh(canvas)
            jax.block_until_ready(st)
            hook(blk, t0r, time.perf_counter())
            return st

        state = timed_refresh(x, 0) if cached else None
        refresh_fwd = 1.0 if cached else 0.0
        fused = dcfg.fused_loop and strat.supports_fused
        if fused:
            run = self._cached_block_runner(strat) if cached \
                else self._plain_runner(strat, extras)
            steps = jnp.zeros((), jnp.int32)
            fwd = jnp.zeros((), jnp.float32)
            for blk in range(num_blocks):
                lo = lp + blk * bs
                if cached and blk > 0 and dcfg.cache_refresh == "block":
                    state = timed_refresh(x, blk)
                    refresh_fwd += 1.0
                if cached:
                    x, rng, steps, fwd, carry = run(
                        x, rng, jnp.int32(lo), jnp.asarray(sched[blk]),
                        steps, fwd, carry, state)
                else:
                    x, rng, steps, fwd, carry = run(
                        x, rng, jnp.int32(lo), jnp.asarray(sched[blk]),
                        steps, fwd, carry)
                yield BlockEvent(blk, lo, lo + bs, x)
            # one sync for the whole decode: canvas + both stats counters
            x.block_until_ready()
            stats.steps = int(jax.device_get(steps))
            stats.forward_equivalents = float(jax.device_get(fwd)) \
                + refresh_fwd
        else:
            cfwd = self._cached_forward_fn() if cached \
                else self._host_model_fn(extras)
            win, static_lo = window_geometry(dcfg, total) if cached \
                else (total, 0)
            last = sched.shape[1] - 1
            for blk in range(num_blocks):
                lo, hi = lp + blk * bs, lp + (blk + 1) * bs
                if cached and blk > 0 and dcfg.cache_refresh == "block":
                    state = timed_refresh(x, blk)
                    refresh_fwd += 1.0
                # live window: full canvas when uncached; the policy's
                # fixed-width slice when cached (window-relative coords,
                # mirroring drive_cached_block)
                win_lo = 0 if not cached else \
                    (lo if static_lo is None else static_lo)
                x_win = x[:, win_lo:win_lo + win]
                wpos = win_lo + jnp.arange(win)
                in_block = (wpos >= lo) & (wpos < hi)
                scale = win / total if cached else 1.0
                if cached:
                    def mf(w, _st=state, _lo=win_lo):
                        return cfwd(w, jnp.int32(_lo),
                                    _tile_state(_st, w.shape[0] // b))
                    wcarry = carry_window(strat, carry, win_lo, win)
                else:
                    mf, wcarry = cfwd, carry
                wcarry = strat.begin_block(wcarry, x_win, in_block)
                # guard: a strategy always commits ≥1 token/example/step,
                # so a block can never need more than bs·4 steps
                for i in range(bs * 4):
                    active = in_block[None, :] & \
                        (x_win == cfg.mask_token_id)
                    if not bool(jax.device_get(jnp.any(active))):
                        break
                    rng, step_rng = jax.random.split(rng)
                    n = int(sched[blk, min(i, last)])
                    x_win, wcarry, fwd_n = strat.step(
                        step_rng, wcarry, x_win, active, mf, cfg, dcfg, n)
                    stats.steps += 1
                    stats.forward_equivalents += fwd_n * scale
                if cached:
                    x = jax.lax.dynamic_update_slice_in_dim(
                        x, x_win, win_lo, axis=1)
                    carry = carry_unwindow(strat, carry, wcarry, win_lo)
                else:
                    x, carry = x_win, wcarry
                yield BlockEvent(blk, lo, hi, x)
            x.block_until_ready()
            stats.forward_equivalents += refresh_fwd
        if isinstance(strat, TracingStrategy):
            stats.trace = strat.extract(carry)
        self._merge_carry_stats(stats, strat, carry)
        stats.wall_time = time.perf_counter() - t0
        return x, stats

    @staticmethod
    def _check_extras(extras) -> None:
        unknown = set(extras) - _CONDITIONING_KEYS
        if unknown:
            raise TypeError(
                f"got unexpected keyword argument(s) {sorted(unknown)}; "
                f"conditioning extras must be one of "
                f"{sorted(_CONDITIONING_KEYS)}")

    def _check_cached(self, extras) -> None:
        """Entry validation for ``cache_policy != 'none'`` decodes."""
        validate_cache_policy(self.cfg, self.dcfg)
        if self._params is None:
            raise ValueError(
                "cache_policy != 'none' requires a Decoder built from "
                "params (a bare model_fn cannot drive the cache capture "
                "or the windowed forwards)")
        if extras:
            raise ValueError(
                "conditioning extras (enc_embeds / patch_embeds) are not "
                "supported with cache_policy != 'none': the cache capture "
                "runs the text stack only — decode uncached, or drop the "
                "conditioning")

    @staticmethod
    def _merge_carry_stats(stats: SampleStats, strat: Strategy,
                           carry) -> None:
        """Read the strategy's observational counters out of the final
        carry into SampleStats (one host sync per decode, not per step)."""
        pc = strat.phase_counts(carry)
        if pc:
            stats.phase_counts = pc
        for key, val in strat.carry_stats(carry).items():
            if not hasattr(stats, key):
                raise AttributeError(
                    f"strategy {strat.name!r} reported carry stat {key!r} "
                    f"which is not a SampleStats field")
            setattr(stats, key, val)

    # -- introspection -----------------------------------------------------
    def cache_info(self) -> CacheInfo:
        """Counters of the runner cache this Decoder resolves against."""
        return self._cache.info()
