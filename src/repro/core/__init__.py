"""The paper's contribution: foreseeing decoding for masked-diffusion LMs.

Public API:
  masking     — forward (noising) process, inference start states
  loss        — Eq. 4 masked cross-entropy
  confidence  — C_local metrics + the C_global (foreseeing) estimator
  strategies  — the Strategy protocol + registry; Random/Probability/
                Margin/Entropy + EB + WINO baselines
  fdm         — Algorithm 1 (FDM)
  fdm_a       — Algorithm 2 (FDM-A, three-phase adaptive)
  wino        — carry-ful WINO revocation (cross-step verify, budgeted
                un-commit; the pending set rides the strategy carry)
  extrapolate — confidence extrapolation / local determinism propagation
                (trajectory carry; skips model forwards outright)
  decoder     — the first-class Decoder: block orchestration for every
                cache policy (none/prefix/dual), cross-call runner cache,
                streaming
  loop        — device-resident fused drivers (plain + KV-cached)
  sampler     — ``make_model_fn``, the conditioned-forward helper
  tracebuffer — on-device step telemetry (``dcfg.trace``): the fixed-
                shape TraceBuffer carry adapter + the DecodeTrace
                host read-back
"""
from repro.core.confidence import (Scores, global_confidence,
                                   local_confidence, score_logits)
from repro.core.decoder import (BlockEvent, CacheInfo, Decoder, SampleStats,
                                clear_decode_cache, decode_cache_info,
                                decode_cache_scope,
                                reset_decode_cache_stats,
                                validate_cache_policy)
from repro.core.extrapolate import ExtrapolationStrategy
from repro.core.fdm import FDMStrategy, fdm_select, fdm_step
from repro.core.fdm_a import (FDMAStrategy, fdm_a_plan, fdm_a_step,
                              fdm_a_step_fused)
from repro.core.wino import WINORevocationStrategy
from repro.core.loop import (drive_block, drive_cached_block, drive_request,
                             drive_request_cached)
from repro.core.loss import masked_cross_entropy, token_accuracy
from repro.core.masking import (apply_mask, fully_masked, mask_positions,
                                sample_mask_ratio)
from repro.core.sampler import make_model_fn
from repro.core.strategies import (StatelessStrategy, Strategy,
                                   available_strategies, commit_topn,
                                   rank_desc,
                                   register_strategy, resolve_strategy,
                                   unregister_strategy)
from repro.core.tracebuffer import (DecodeTrace, TracingStrategy,
                                    trace_capacity, tracing)

__all__ = [
    "Scores", "score_logits", "local_confidence", "global_confidence",
    "Strategy", "StatelessStrategy", "register_strategy",
    "unregister_strategy", "resolve_strategy", "available_strategies",
    "Decoder", "BlockEvent", "CacheInfo", "decode_cache_info",
    "clear_decode_cache",
    "decode_cache_scope", "reset_decode_cache_stats",
    "validate_cache_policy",
    "FDMStrategy", "fdm_step", "fdm_select",
    "FDMAStrategy", "fdm_a_step", "fdm_a_step_fused", "fdm_a_plan",
    "WINORevocationStrategy", "ExtrapolationStrategy",
    "drive_block", "drive_request",
    "drive_cached_block", "drive_request_cached",
    "masked_cross_entropy", "token_accuracy",
    "apply_mask", "fully_masked", "mask_positions", "sample_mask_ratio",
    "SampleStats", "make_model_fn",
    "DecodeTrace", "TracingStrategy", "tracing", "trace_capacity",
    "commit_topn", "rank_desc",
]
