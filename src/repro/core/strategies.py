"""Baseline decoding-order strategies (the paper's comparison set).

Heuristics (§2, Table 2): Random / Probability / Margin / Entropy — commit
the n most confident masked positions per step, confidence judged locally.

Dynamic baselines (§5, Table 3):
* **EB** (Ben-Hamu et al., 2025): entropy-bounded parallel unmasking —
  commit every position whose predictive entropy is below a bound (always
  at least the single most confident one).
* **WINO** (Hong et al., 2025): wide-in narrow-out — greedily commit every
  position above τ₁, then re-verify with one extra forward pass and revoke
  (re-mask) commitments whose re-scored confidence drops below τ₂ (the top
  confidence token is always kept so progress is guaranteed).

All strategies share the same jit-friendly primitive: a per-example top-n
masked commit with fixed shapes (ranking instead of dynamic gather).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.confidence import (Scores, local_confidence, pallas_enabled,
                                   score_logits)

ModelFn = Callable[[jnp.ndarray], jnp.ndarray]   # tokens (B,L) -> logits

NEG = -1e30


def rank_desc(conf: jnp.ndarray) -> jnp.ndarray:
    """Dense descending rank per row: rank 0 = highest confidence."""
    order = jnp.argsort(-conf, axis=-1)
    return jnp.argsort(order, axis=-1)


def commit_topn(x: jnp.ndarray, conf: jnp.ndarray, cand: jnp.ndarray,
                eligible: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Commit cand tokens at the top-n eligible positions per example.

    conf (B,L) ranking score; eligible (B,L) bool; n (B,) or scalar int.
    """
    c = jnp.where(eligible, conf, NEG)
    ranks = rank_desc(c)
    n_arr = jnp.asarray(n)
    if n_arr.ndim == 0:
        n_arr = n_arr[None].repeat(x.shape[0], 0)
    commit = eligible & (ranks < n_arr[:, None])
    return jnp.where(commit, cand, x)


# --------------------------------------------------------------------------
# strategy step functions
# --------------------------------------------------------------------------
# signature: step(rng, x, active, model_fn, cfg, dcfg, n) ->
#   (new_x, extra_forwards) — `active` marks the current semi-AR block's
#   still-masked positions; the caller already ran one forward whose logits
#   we recompute inside model_fn for jit friendliness (the sampler fuses).

def heuristic_step(metric: str):
    def step(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
             dcfg: DecodeConfig, n) -> Tuple[jnp.ndarray, int]:
        logits = model_fn(x)
        s = score_logits(logits, pallas_enabled(dcfg))
        if metric == "random":
            conf = jax.random.uniform(rng, x.shape)
        else:
            conf = local_confidence(s, metric)
        return commit_topn(x, conf, s.argmax, active, n), 1
    return step


def eb_step(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
            dcfg: DecodeConfig, n) -> Tuple[jnp.ndarray, int]:
    """Entropy-bounded: commit everything with H < bound, at least one."""
    logits = model_fn(x)
    s = score_logits(logits, pallas_enabled(dcfg))
    low_entropy = (-s.neg_entropy) < dcfg.eb_threshold
    conf = jnp.where(active, s.neg_entropy, NEG)
    best = rank_desc(conf) == 0                       # guarantee progress
    commit = active & (low_entropy | best)
    return jnp.where(commit, s.argmax, x), 1


def wino_step(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
              dcfg: DecodeConfig, n) -> Tuple[jnp.ndarray, int]:
    """Wide-in (commit > τ₁) then narrow-out (revoke < τ₂ on re-score)."""
    logits = model_fn(x)
    s = score_logits(logits, pallas_enabled(dcfg))
    conf = jnp.where(active, s.max_prob, NEG)
    best = rank_desc(conf) == 0
    wide = active & ((s.max_prob > dcfg.wino_tau1) | best)
    x_wide = jnp.where(wide, s.argmax, x)
    # verify: re-score the committed tokens in their new context
    logits2 = model_fn(x_wide)
    logp2 = jax.nn.log_softmax(logits2.astype(jnp.float32), axis=-1)
    p_committed = jnp.exp(jnp.take_along_axis(
        logp2, x_wide[..., None], axis=-1)[..., 0])
    revoke = wide & (p_committed < dcfg.wino_tau2) & ~best
    return jnp.where(revoke, cfg.mask_token_id, x_wide), 2


def get_strategy(name: str, fused: bool = False):
    """Look up a step function.  ``fused=True`` returns the fully traceable
    variant (safe inside ``lax.while_loop``): identical for every strategy
    except FDM-A, whose host-side early-out becomes a ``lax.cond``.
    """
    from repro.core.fdm import fdm_step
    from repro.core.fdm_a import fdm_a_step, fdm_a_step_fused
    table = {
        "random": heuristic_step("random"),
        "probability": heuristic_step("probability"),
        "margin": heuristic_step("margin"),
        "entropy": heuristic_step("entropy"),
        "eb": eb_step,
        "wino": wino_step,
        "fdm": fdm_step,
        "fdm_a": fdm_a_step_fused if fused else fdm_a_step,
    }
    if name not in table:
        raise KeyError(f"unknown strategy {name!r}; have {sorted(table)}")
    return table[name]
