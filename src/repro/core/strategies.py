"""Decoding strategies: the ``Strategy`` protocol, the registry, and the
paper's comparison set.

A strategy is a first-class object (not a bare step function) so it can
carry per-decode state, declare its own fused (trace-safe) form, and plug
into the ``Decoder`` block loop (``core/decoder.py``) without touching the
sampler.  The protocol:

  * ``init_carry(cfg, dcfg) -> carry`` — per-decode state threaded through
    every step and across blocks.  Must be a fixed-shape pytree (it rides
    the ``lax.while_loop`` carry on the fused path); ``()`` for stateless
    strategies.  Strategies whose carry is *positional* (per canvas
    column) override ``init_carry_shaped`` instead and set
    ``positional_carry = True`` — see that method's docstring for the
    required ``(positional, global)`` carry structure.
  * ``begin_block(carry, x, in_block) -> carry`` — traceable block-entry
    hook, fired by every driver before a block's first step (WINO
    revocation uses it to drop cross-block pending commits so streaming
    stays final-commit-only).  Default: identity.
  * ``step(rng, carry, x, active, model_fn, cfg, dcfg, n)
    -> (new_x, new_carry, forwards)`` — one denoising step.  May touch the
    host (sync, early-out) — this is the variant the legacy host loop runs.
  * ``fused_step(...)`` — same signature, fully traceable (safe inside
    ``lax.while_loop``); defaults to ``step``.  Override when ``step``
    needs host control flow (FDM-A's early-out becomes a ``lax.cond``).
  * host-side stats: ``phase_counts(carry)`` and ``carry_stats(carry)``
    read observational counters (phase histograms, revocation and
    skipped-forward counts) out of the *final* carry into ``SampleStats``.
  * metadata: ``supports_fused`` (has a trace-safe form at all),
    ``forwards_per_step(dcfg)`` (nominal batched-forward count per step —
    an upper bound for adaptive strategies), ``carry_is_observational``
    and ``positional_carry`` (see the attribute comments).

Registered strategies (``register_strategy`` / ``resolve_strategy``):

* Heuristics (§2, Table 2): Random / Probability / Margin / Entropy —
  commit the n most confident masked positions per step, judged locally.
* Dynamic baselines (§5, Table 3): **EB** (Ben-Hamu et al., 2025)
  entropy-bounded parallel unmasking; **WINO** (Hong et al., 2025)
  wide-in narrow-out commit-then-revoke.
* Carry-ful builtins (the first strategies to use a decode-steering
  carry): **wino_r** (``core/wino.py``) — WINO revocation with
  cross-step pending-commit state and a per-request revocation budget,
  one forward per step; **extrapolate** (``core/extrapolate.py``) —
  confidence-trajectory extrapolation / local determinism propagation
  (Kong et al., 2025): positions whose confidence trajectory
  extrapolates past a threshold commit early *without* a fresh forward.
* **FDM / FDM-A** (the paper's contribution) register themselves from
  ``core/fdm.py`` / ``core/fdm_a.py``.

Third-party strategies can register via ``register_strategy`` directly or
through the ``repro.strategies`` entry-point group — no edits to ``core/``
required.

All strategies share the same jit-friendly primitive: a per-example top-n
masked commit with fixed shapes (ranking instead of dynamic gather).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.confidence import (local_confidence, pallas_enabled,
                                   score_logits)

ModelFn = Callable[[jnp.ndarray], jnp.ndarray]   # tokens (B,L) -> logits

NEG = -1e30


def rank_desc(conf: jnp.ndarray) -> jnp.ndarray:
    """Dense descending rank per row: rank 0 = highest confidence."""
    order = jnp.argsort(-conf, axis=-1)
    return jnp.argsort(order, axis=-1)


def commit_topn(x: jnp.ndarray, conf: jnp.ndarray, cand: jnp.ndarray,
                eligible: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Commit cand tokens at the top-n eligible positions per example.

    conf (B,L) ranking score; eligible (B,L) bool; n (B,) or scalar int.
    """
    c = jnp.where(eligible, conf, NEG)
    ranks = rank_desc(c)
    n_arr = jnp.asarray(n)
    if n_arr.ndim == 0:
        n_arr = n_arr[None].repeat(x.shape[0], 0)
    commit = eligible & (ranks < n_arr[:, None])
    return jnp.where(commit, cand, x)


# --------------------------------------------------------------------------
# the Strategy protocol
# --------------------------------------------------------------------------

class Strategy:
    """Base class for decoding strategies (see module docstring).

    Subclasses implement ``step`` (and ``fused_step`` when ``step`` needs
    host control flow).  ``active`` marks the current semi-AR block's
    still-masked positions; ``n`` is the caller's nominal commit width.
    """

    name: str = ""
    supports_fused: bool = True      # fused_step is lax.while_loop-safe
    carry_is_observational: bool = False
    # True = the carry only *records* (stats counters like FDM-A's phase
    # histogram) and never changes the decode; safe to drop/reset.  False
    # (default) = the carry steers decoding and must be threaded intact.
    positional_carry: bool = False
    # True = the carry is the 2-tuple ``(positional, global)`` described
    # by ``init_carry_shaped``: the positional part's leaves are
    # column-aligned with the canvas, so the cached path can slice them
    # alongside its live window.  False (default) = the carry is opaque
    # and rides every driver whole.
    trace_confidence_tap: bool = False
    # True = the strategy's FIRST full-canvas model_fn call per step is
    # unconditional, so the tracing adapter (core/tracebuffer.py) may
    # wrap model_fn and capture that call's logits for commit-confidence
    # attribution.  False (default) = the call may sit inside a lax.cond
    # branch (extrapolate's skip) where a tap would leak tracers; the
    # adapter falls back to ``trace_confidence``.

    def forwards_per_step(self, dcfg: DecodeConfig) -> float:
        """Nominal batched-forward count per step (upper bound for
        adaptive strategies); used for budgeting, not accounting — the
        step functions return the exact count."""
        return 1.0

    def init_carry(self, cfg: ModelConfig, dcfg: DecodeConfig):
        """Per-decode strategy state.  Fixed-shape pytree; ``()`` = none."""
        return ()

    def init_carry_shaped(self, cfg: ModelConfig, dcfg: DecodeConfig,
                          batch: int, length: int):
        """Shape-aware carry init: ``(batch, length)`` is the (B, L) of
        the canvas the decode will run on (prompt + generation).

        Strategies with per-position state (``positional_carry = True``)
        override THIS method and must return the 2-tuple
        ``(positional, global)`` where every leaf of ``positional`` has
        leading shape ``(B, L, ...)`` column-aligned with the canvas
        (the cached path slices these to its live window and writes them
        back per block) and ``global`` is any fixed-shape pytree that
        rides every driver whole (budgets, counters).  The default
        delegates to the shape-free ``init_carry``."""
        return self.init_carry(cfg, dcfg)

    def begin_block(self, carry, x, in_block):
        """Traceable block-entry hook: called by every driver (host,
        per-block fused, whole-request fused, cached) right before a
        block's first denoising step.  ``in_block`` is the (L,) bool
        column mask of the new block over ``x``'s columns.  Strategies
        with cross-block state that must not leak into a freshly started
        block (WINO revocation's pending commits — a block that already
        streamed may never be re-opened) reset it here."""
        return carry

    def phase_counts(self, carry) -> Dict[str, int]:
        """Host-side: per-phase step counts extracted from the *final*
        carry, for ``SampleStats.phase_counts``.  Strategies that count
        phases on-device (FDM-A accumulates a ``(4,)`` int32 in its carry)
        override this; the default reports none."""
        return {}

    def carry_stats(self, carry) -> Dict[str, float]:
        """Host-side: observational counters extracted from the *final*
        carry and merged onto same-named ``SampleStats`` fields
        (``revocations``, ``skipped_forwards``).  One ``device_get`` at
        the end of decode — never per step."""
        return {}

    def trace_confidence(self, carry, dcfg: DecodeConfig):
        """Trace-safe (B, L) confidence map read from the POST-step
        carry, for strategies whose commit confidence lives in the carry
        rather than a tappable forward (``trace_confidence_tap = False``
        with cross-step state — extrapolate's trajectory).  ``None``
        (default) = no confidence attribution; the tracing adapter
        records NaN at commits."""
        return None

    def trace_phase(self, carry_before, carry_after):
        """Trace-safe scalar int32 phase id derived from one step's
        carry transition, for phase-switching strategies (FDM-A's
        explore/accel/local_only/balance).  ``None`` (default) = no
        phase attribution (recorded as -1)."""
        return None

    def step(self, rng, carry, x, active, model_fn: ModelFn,
             cfg: ModelConfig, dcfg: DecodeConfig, n) -> Tuple:
        raise NotImplementedError

    def fused_step(self, rng, carry, x, active, model_fn: ModelFn,
                   cfg: ModelConfig, dcfg: DecodeConfig, n) -> Tuple:
        """Trace-safe variant; default assumes ``step`` already is."""
        return self.step(rng, carry, x, active, model_fn, cfg, dcfg, n)

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class StatelessStrategy(Strategy):
    """Adapter lifting a carry-less step function into the protocol.

    ``step_fn(rng, x, active, model_fn, cfg, dcfg, n) -> (x, forwards)``
    is the pre-Decoder signature; ``fused_fn`` (optional) is its
    trace-safe form.
    """

    # every builtin stateless step opens with one unconditional
    # full-canvas model_fn(x) — safe for the tracing adapter to tap
    trace_confidence_tap = True

    def __init__(self, name: str, step_fn: Callable,
                 fused_fn: Optional[Callable] = None,
                 forwards: float = 1.0, supports_fused: bool = True):
        self.name = name
        self._step_fn = step_fn
        self._fused_fn = fused_fn or step_fn
        self._forwards = forwards
        self.supports_fused = supports_fused

    def forwards_per_step(self, dcfg: DecodeConfig) -> float:
        return float(self._forwards)

    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        new_x, fwd = self._step_fn(rng, x, active, model_fn, cfg, dcfg, n)
        return new_x, carry, fwd

    def fused_step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        new_x, fwd = self._fused_fn(rng, x, active, model_fn, cfg, dcfg, n)
        return new_x, carry, fwd


def as_strategy(obj) -> Strategy:
    """Coerce a Strategy, registered name, or legacy step callable."""
    if isinstance(obj, Strategy):
        return obj
    if isinstance(obj, str):
        return resolve_strategy(obj)
    if callable(obj):
        return StatelessStrategy(getattr(obj, "__name__", "anonymous"), obj)
    raise TypeError(f"cannot interpret {obj!r} as a decoding strategy")


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, Strategy] = {}
_BUILTINS_LOADED = False
_ENTRY_POINTS_LOADED = False


def register_strategy(strategy=None, *, name: Optional[str] = None,
                      replace: bool = False):
    """Register a ``Strategy`` (instance or zero-arg class).

    Usable as a decorator::

        @register_strategy
        class MyStrategy(Strategy):
            name = "mine"
            ...

    Third-party packages can also publish strategies under the
    ``repro.strategies`` entry-point group; they are loaded lazily on the
    first unresolved lookup.
    """
    if strategy is None:                       # decorator-with-args form
        return lambda s: register_strategy(s, name=name, replace=replace)
    obj = strategy() if isinstance(strategy, type) else strategy
    if not isinstance(obj, Strategy):
        raise TypeError(f"{strategy!r} is not a Strategy")
    key = name or obj.name
    if not key:
        raise ValueError(f"{obj!r} has no name")
    if key in _REGISTRY and not replace and _REGISTRY[key] is not obj:
        raise ValueError(f"strategy {key!r} already registered "
                         "(pass replace=True to override)")
    _REGISTRY[key] = obj
    return strategy


def unregister_strategy(name: str) -> None:
    _REGISTRY.pop(name, None)


def _ensure_builtins() -> None:
    """Builtins that live in their own modules register at import."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    import repro.core.extrapolate    # noqa: F401  (registers "extrapolate")
    import repro.core.fdm            # noqa: F401  (registers "fdm")
    import repro.core.fdm_a          # noqa: F401  (registers "fdm_a")
    import repro.core.wino           # noqa: F401  (registers "wino_r")


def _load_entry_points() -> None:
    global _ENTRY_POINTS_LOADED
    if _ENTRY_POINTS_LOADED:
        return
    _ENTRY_POINTS_LOADED = True
    try:
        from importlib.metadata import entry_points
        eps = entry_points(group="repro.strategies")
    except Exception:
        return
    for ep in eps:
        try:
            obj = ep.load()
            register_strategy(obj, name=ep.name, replace=False)
        except Exception:
            continue                  # a broken plugin must not kill decode


def resolve_strategy(name: str) -> Strategy:
    """Look up a registered ``Strategy`` object by name."""
    if isinstance(name, Strategy):
        return name
    _ensure_builtins()
    if name not in _REGISTRY:
        _load_entry_points()
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_strategies() -> Tuple[str, ...]:
    _ensure_builtins()
    _load_entry_points()     # list what resolve_strategy would accept
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# baseline step functions (kept as plain functions; adapters register them)
# --------------------------------------------------------------------------
# legacy signature: step(rng, x, active, model_fn, cfg, dcfg, n) ->
#   (new_x, extra_forwards)

def heuristic_step(metric: str):
    def step(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
             dcfg: DecodeConfig, n) -> Tuple[jnp.ndarray, int]:
        logits = model_fn(x)
        s = score_logits(logits, pallas_enabled(dcfg))
        if metric == "random":
            conf = jax.random.uniform(rng, x.shape)
        else:
            conf = local_confidence(s, metric)
        return commit_topn(x, conf, s.argmax, active, n), 1
    return step


def eb_step(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
            dcfg: DecodeConfig, n) -> Tuple[jnp.ndarray, int]:
    """Entropy-bounded: commit everything with H < bound, at least one."""
    logits = model_fn(x)
    s = score_logits(logits, pallas_enabled(dcfg))
    low_entropy = (-s.neg_entropy) < dcfg.eb_threshold
    conf = jnp.where(active, s.neg_entropy, NEG)
    best = rank_desc(conf) == 0                       # guarantee progress
    commit = active & (low_entropy | best)
    return jnp.where(commit, s.argmax, x), 1


def wino_step(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
              dcfg: DecodeConfig, n) -> Tuple[jnp.ndarray, int]:
    """Wide-in (commit > τ₁) then narrow-out (revoke < τ₂ on re-score)."""
    logits = model_fn(x)
    s = score_logits(logits, pallas_enabled(dcfg))
    conf = jnp.where(active, s.max_prob, NEG)
    best = rank_desc(conf) == 0
    wide = active & ((s.max_prob > dcfg.wino_tau1) | best)
    x_wide = jnp.where(wide, s.argmax, x)
    # verify: re-score the committed tokens in their new context
    logits2 = model_fn(x_wide)
    logp2 = jax.nn.log_softmax(logits2.astype(jnp.float32), axis=-1)
    p_committed = jnp.exp(jnp.take_along_axis(
        logp2, x_wide[..., None], axis=-1)[..., 0])
    revoke = wide & (p_committed < dcfg.wino_tau2) & ~best
    return jnp.where(revoke, cfg.mask_token_id, x_wide), 2


for _metric in ("random", "probability", "margin", "entropy"):
    register_strategy(StatelessStrategy(_metric, heuristic_step(_metric)))
register_strategy(StatelessStrategy("eb", eb_step))
register_strategy(StatelessStrategy("wino", wino_step, forwards=2.0))
