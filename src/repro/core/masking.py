"""The forward (noising) process of masked-diffusion LMs.

LLaDA's training corruption (Eq. 4): sample a mask ratio t ~ U(0, 1] per
example, independently replace each answer token with ``Mask`` w.p. t.  The
loss reweights masked positions by 1/t so the objective is an exact bound on
the data NLL (Nie et al., 2025).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def sample_mask_ratio(rng, batch: int, eps: float = 1e-3) -> jnp.ndarray:
    """t ~ U(eps, 1] per example."""
    return jax.random.uniform(rng, (batch,), minval=eps, maxval=1.0)


def apply_mask(rng, tokens: jnp.ndarray, t: jnp.ndarray,
               cfg: ModelConfig,
               maskable: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Corrupt ``tokens`` (B, L): each maskable position -> Mask w.p. t[b].

    ``maskable`` (B, L) bool restricts corruption to the answer region
    (prompt tokens are conditioning and never masked).  Returns
    (corrupted tokens, mask indicator (B, L) bool).
    """
    b, l = tokens.shape
    u = jax.random.uniform(rng, (b, l))
    masked = u < t[:, None]
    if maskable is not None:
        masked = masked & maskable
    corrupted = jnp.where(masked, cfg.mask_token_id, tokens)
    return corrupted, masked


def fully_masked(cfg: ModelConfig, prompt: jnp.ndarray,
                 gen_length: int) -> jnp.ndarray:
    """Inference start state: [prompt | Mask × gen_length]."""
    b = prompt.shape[0]
    tail = jnp.full((b, gen_length), cfg.mask_token_id, prompt.dtype)
    return jnp.concatenate([prompt, tail], axis=1)


def mask_positions(tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """(B, L) bool: which positions are still masked."""
    return tokens == cfg.mask_token_id
