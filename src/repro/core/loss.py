"""Eq. 4 — the masked-diffusion training objective.

L(θ) = E_{x, t} [ 1/t · Σ_j 1[x_t^(j) = Mask] · (-log p_θ(x^(j) | x_t, q)) ]

The 1/t reweighting makes the objective an upper bound on NLL; aux losses
(MoE load-balance) are added by the caller.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def masked_cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                         masked: jnp.ndarray, t: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """logits (B,L,V) f32, targets (B,L) int, masked (B,L) bool, t (B,).

    Returns (scalar loss, per-example masked-token count).
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    w = masked.astype(jnp.float32) / jnp.maximum(t, 1e-3)[:, None]
    count = jnp.maximum(jnp.sum(masked), 1)
    loss = jnp.sum(nll * w) / count
    return loss, jnp.sum(masked, axis=-1)


def token_accuracy(logits: jnp.ndarray, targets: jnp.ndarray,
                   masked: jnp.ndarray) -> jnp.ndarray:
    """Fraction of masked positions whose argmax equals the target."""
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == targets) & masked
    return jnp.sum(hit) / jnp.maximum(jnp.sum(masked), 1)
