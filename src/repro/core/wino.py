"""WINO revocation — the first carry-ful builtin strategy (``wino_r``).

The stateless ``wino`` baseline (``core/strategies.py``) verifies its
wide-in commits with a SECOND forward inside the same step: commit
everything above τ₁, re-forward, revoke what fell below τ₂ — 2 forwards
per step.  The carry-ful variant amortises verification across steps
instead (the ``Strategy.init_carry`` protocol makes the cross-step state
free to host):

* **commit (wide-in)** — every active position above τ₁, plus the
  schedule's top-``n`` (progress guarantee), is committed and flagged
  *pending* in the carry;
* **verify (narrow-out, next step)** — the next step's ONE regular
  forward re-scores the pending tokens in their updated context; a
  pending token whose re-scored probability fell below
  ``wino_revoke_tau`` is revoked:
  re-masked on the canvas and re-decoded by a later step, spending one
  unit of the per-example revocation budget.  Survivors are confirmed
  and leave the pending set.

One forward per step, same as plain confidence decoding — the revocation
machinery rides the forward the step pays anyway.

Consequences for the loop machinery (see ``Decoder._geometry`` and
``drive_block``): a step's NET commit count can be negative, so blocks
may legitimately run past their commit-width schedule — the schedule
pads with its final width (never zero) so overrun steps keep making
progress, and the ``block_size·4`` safety cap plus the finite budget
bound the overrun.  Revocation is strictly block-local: ``begin_block``
clears the pending set, so a committed block (already streamed via
``on_block_committed``) can never be re-opened — streaming remains
final-commit-only.  Commits made on a block's last step exit the block
unverified (the loop ends when no masks remain); verification is
best-effort within the block's step budget, exactly WINO's pipelined
check.

The carry is positional (``positional_carry = True``):

* positional part: ``pending`` (B, L) bool — the positions committed
  but not yet re-verified (sliced to the live window on the cached
  path);
* global part: ``budget`` (B,) i32 — remaining revocations per example;
  ``revoked`` () i32 — observational total, read into
  ``SampleStats.revocations``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.confidence import pallas_enabled, score_logits
from repro.core.strategies import (NEG, ModelFn, Strategy, rank_desc,
                                   register_strategy)


class WINORevocationStrategy(Strategy):
    """WINO-style commit-then-revoke with cross-step carry state.

    The step is pure vectorised array math (the budget cap is a ranking,
    not a host loop), so it is trace-safe as written — ``fused_step`` is
    the default ``step`` and all three drivers are bit-identical.
    """

    name = "wino_r"
    positional_carry = True
    trace_confidence_tap = True    # one unconditional full-canvas forward
                                   # per step — the tap sees the scores
                                   # the wide-in commit used

    def init_carry(self, cfg: ModelConfig, dcfg: DecodeConfig):
        raise TypeError(
            "strategy 'wino_r' carries per-decode positional state; it "
            "needs the canvas shape — decode through Decoder (which calls "
            "init_carry_shaped), not the deprecated carry-less entry "
            "points")

    def init_carry_shaped(self, cfg: ModelConfig, dcfg: DecodeConfig,
                          batch: int, length: int):
        pending = jnp.zeros((batch, length), bool)
        budget = jnp.full((batch,), dcfg.wino_revoke_budget, jnp.int32)
        revoked = jnp.zeros((), jnp.int32)
        return (pending,), (budget, revoked)

    def begin_block(self, carry, x, in_block):
        # pending commits never cross a block boundary: the previous
        # block has already streamed, so its last-step commits are final
        (pending,), glob = carry
        return (jnp.zeros_like(pending),), glob

    def carry_stats(self, carry) -> Dict[str, float]:
        _, (_, revoked) = carry
        return {"revocations": float(jax.device_get(revoked))}

    def step(self, rng, carry, x, active, model_fn: ModelFn,
             cfg: ModelConfig, dcfg: DecodeConfig, n) -> Tuple:
        (pending,), (budget, revoked) = carry
        logits = model_fn(x)
        s = score_logits(logits, pallas_enabled(dcfg))

        # -- narrow-out: verify the pending commits under the fresh scores
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        p_tok = jnp.exp(jnp.take_along_axis(
            logp, x[..., None], axis=-1)[..., 0])
        fail = pending & (p_tok < dcfg.wino_revoke_tau)
        # budget cap: revoke the worst offenders (lowest re-score) first
        fail_rank = rank_desc(jnp.where(fail, -p_tok, NEG))
        revoke = fail & (fail_rank < budget[:, None])
        x = jnp.where(revoke, cfg.mask_token_id, x)
        budget = budget - jnp.sum(revoke, axis=-1, dtype=jnp.int32)
        revoked = revoked + jnp.sum(revoke, dtype=jnp.int32)

        # -- wide-in: τ₁ overflow plus the schedule's top-n floor.
        # `active` is the step-entry mask set: just-revoked positions are
        # NOT in it, so they re-decode on a later step with a fresh score.
        n_arr = jnp.broadcast_to(jnp.asarray(n, jnp.int32), (x.shape[0],))
        conf = jnp.where(active, s.max_prob, NEG)
        commit = active & ((s.max_prob > dcfg.wino_tau1)
                           | (rank_desc(conf) < n_arr[:, None]))
        x = jnp.where(commit, s.argmax, x)
        # every previously-pending position was verified (or revoked)
        # this step, so the new pending set is exactly this step's commits
        return x, ((commit,), (budget, revoked)), 1


register_strategy(WINORevocationStrategy())
