"""FDM — the Foreseeing Decoding Method (Algorithm 1).

Per step:
  1. one forward pass scores every masked position; the argmax token of each
     masked position is its *candidate*;
  2. candidates with local confidence p ≤ γ are pruned (dynamic pruning);
  3. the Top-K surviving candidates by C_local form the search set Λ;
  4. **foreseeing**: each λ ∈ Λ is committed into a hypothetical next state;
     all K states are evaluated in ONE batched forward pass (the K candidate
     sequences are folded into the batch axis — the TPU-native replacement
     for the paper's sequential A100 re-queries; semantics of Eq. 15 are
     unchanged, only the schedule);
  5. commit the candidate maximizing C_local + C_global (Eq. 15); if Λ is
     empty, fall back to the pure-local argmax commit.

Generalization to n > 1 tokens per step (used by FDM-A's balance phase):
the top (n-1) candidates by C_local are committed unconditionally (they
would win any local tie-break) and the K candidates ranked n-1 … n+K-2
compete for the last slot via the foreseeing criterion.  With n=1 this is
exactly Algorithm 1.  Recorded as an interpretation choice in DESIGN.md.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.confidence import (global_confidence, pallas_enabled,
                                   score_logits)
from repro.core.strategies import (NEG, ModelFn, StatelessStrategy,
                                   commit_topn, rank_desc, register_strategy)


def fdm_select(x: jnp.ndarray, logits: jnp.ndarray, active: jnp.ndarray,
               model_fn: ModelFn, cfg: ModelConfig, k: int,
               gamma, n, use_kernel: bool = None) -> Tuple[jnp.ndarray, int]:
    """The FDM search core. gamma/n may be scalars or (B,) arrays.

    Returns (new_x, extra_forward_count).
    """
    b, l = x.shape
    s = score_logits(logits, use_kernel)
    gamma_arr = jnp.broadcast_to(jnp.asarray(gamma, jnp.float32), (b,))
    n_arr = jnp.broadcast_to(jnp.asarray(n, jnp.int32), (b,))

    c_local_log = jnp.log(jnp.maximum(s.max_prob, 1e-30))     # Eq. 11

    # Λ construction: prune p ≤ γ, rank by C_local, keep K contenders for
    # the n-th slot; the first n-1 slots are the unconditional "safe set".
    eligible = active & (s.max_prob > gamma_arr[:, None])
    conf_el = jnp.where(eligible, s.max_prob, NEG)
    ranks_el = rank_desc(conf_el)
    safe = eligible & (ranks_el < (n_arr - 1)[:, None])
    contender = eligible & (ranks_el >= (n_arr - 1)[:, None]) \
        & (ranks_el < (n_arr - 1 + k)[:, None])
    has_search = jnp.any(contender, axis=-1)                  # Λ ≠ ∅ per ex.

    x_safe = jnp.where(safe, s.argmax, x)

    # build the K hypothetical next states: commit contender slot j
    # (j-th contender in C_local order) on top of the safe set — one
    # broadcast one-hot build, no per-candidate Python loop
    slot = ranks_el - (n_arr - 1)[:, None]                    # contender slot
    sel_k = contender[None] & \
        (slot[None] == jnp.arange(k)[:, None, None])          # (K, B, L)
    xc = jnp.where(sel_k, s.argmax[None], x_safe[None])       # (K, B, L)
    valid = jnp.any(sel_k, axis=-1)                           # (K, B)

    # ONE batched foreseeing forward over all K candidates
    logits_c = model_fn(xc.reshape(k * b, l)).reshape(k, b, l, -1)
    still_masked = (xc == cfg.mask_token_id)
    c_glob = jax.vmap(global_confidence)(logits_c, still_masked)   # (K, B)
    c_loc = jnp.sum(jnp.where(sel_k, c_local_log[None], 0.0), axis=-1)
    total = jnp.where(valid, c_loc + c_glob, NEG)             # Eq. 15
    winner = jnp.argmax(total, axis=0)                        # (B,)

    win_commit = jnp.take_along_axis(
        sel_k, winner[None, :, None], axis=0)[0]              # (B, L)
    x_search = jnp.where(win_commit, s.argmax, x_safe)

    # Λ = ∅ fallback: pure local top-n commit (no γ filter)
    x_local = commit_topn(x, s.max_prob, s.argmax, active, n_arr)
    new_x = jnp.where(has_search[:, None], x_search, x_local)
    return new_x, k   # K batch-equivalent foreseeing forwards


def fdm_step(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
             dcfg: DecodeConfig, n) -> Tuple[jnp.ndarray, int]:
    """Algorithm 1 with the paper defaults: n=1 token per step."""
    logits = model_fn(x)
    new_x, extra = fdm_select(x, logits, active, model_fn, cfg,
                              k=dcfg.k, gamma=dcfg.gamma, n=1,
                              use_kernel=pallas_enabled(dcfg))
    return new_x, 1 + extra


class FDMStrategy(StatelessStrategy):
    """Algorithm 1 as a registered ``Strategy`` (stateless; the step is
    fully traceable, so the fused form is the step itself)."""

    def __init__(self):
        super().__init__("fdm", fdm_step)

    def forwards_per_step(self, dcfg: DecodeConfig) -> float:
        return 1.0 + dcfg.k        # scoring forward + K-candidate search


register_strategy(FDMStrategy())
