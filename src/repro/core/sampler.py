"""``make_model_fn`` — the supported helper for building a conditioned
forward from params (paper §5.1 pipeline).

The function-style sampler entry points that used to live here
(``generate`` / ``generate_cached``) are gone: the semi-autoregressive
block sampler is the first-class ``Decoder`` object (``core/decoder.py``),
which owns the block loop for every cache policy, the cross-call
compiled-runner cache, RNG threading, stats, and per-block streaming
callbacks.  The old cached entry point maps onto the policy axis::

    Decoder(model_fn, cfg, dcfg).generate(rng, prompt)        # plain
    Decoder(params, cfg,
            replace(dcfg, cache_policy="prefix")).generate(rng, prompt)

(DESIGN.md "The KV cache" has the migration note.)
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.configs.base import ModelConfig
from repro.core.decoder import Decoder, SampleStats  # noqa: F401 (re-export)


def make_model_fn(params, cfg: ModelConfig, **extras) -> Callable:
    """tokens (B', L) -> logits, with conditioning inputs (enc_embeds /
    patch_embeds) tiled to match B'.  FDM folds its K candidates into the
    batch axis (B' = K·B ordered candidate-major), so the conditioning
    must replicate candidate-major too — ``jnp.tile`` does exactly that.
    """
    import jax.numpy as jnp
    from repro.models.model import forward

    # repro-lint: ignore[ANA002] -- build-once helper: callers keep the closure
    # for the model's lifetime and the Decoder runner cache keys on its
    # identity, so the jit cache lives exactly as long as the params it
    # closes over
    @jax.jit
    def model_fn(x):
        kw = {}
        for k, v in extras.items():
            reps = x.shape[0] // v.shape[0]
            kw[k] = jnp.tile(v, (reps,) + (1,) * (v.ndim - 1)) \
                if reps > 1 else v
        return forward(params, x, cfg, **kw)[0]

    return model_fn
