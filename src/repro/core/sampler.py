"""Deprecated function-style sampler entry points (paper §5.1 pipeline).

The semi-autoregressive block sampler — generation length 256 in blocks of
64, free decoding order *within* a block (where the strategy earns its
keep) — now lives in the first-class ``Decoder`` object
(``core/decoder.py``), which owns the block loop for both execution modes,
the cross-call compiled-runner cache, RNG threading, stats, and per-block
streaming callbacks.  Strategies are ``Strategy`` objects in an extensible
registry (``core/strategies.py``).

This module keeps the original free functions as thin deprecation shims
for one release::

    generate(rng, model_fn, prompt, cfg, dcfg)         # plain decoding
    generate_cached(rng, params, prompt, cfg, dcfg)    # frozen-prefix

are token-for-token equivalent to::

    Decoder(model_fn, cfg, dcfg).generate(rng, prompt)
    Decoder(params, cfg, dcfg).generate_cached(rng, prompt)

and share the same runner cache, so mixing old and new call styles costs
no extra compilations.  ``make_model_fn`` remains the supported helper
for building a conditioned forward from params.  New code should construct
a ``Decoder`` directly.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.decoder import Decoder, SampleStats  # noqa: F401 (re-export)


def make_model_fn(params, cfg: ModelConfig, **extras) -> Callable:
    """tokens (B', L) -> logits, with conditioning inputs (enc_embeds /
    patch_embeds) tiled to match B'.  FDM folds its K candidates into the
    batch axis (B' = K·B ordered candidate-major), so the conditioning
    must replicate candidate-major too — ``jnp.tile`` does exactly that.
    """
    import jax.numpy as jnp
    from repro.models.model import forward

    # repro-lint: ignore[ANA002] -- build-once helper: callers keep the closure
    # for the model's lifetime and the Decoder runner cache keys on its
    # identity, so the jit cache lives exactly as long as the params it
    # closes over
    @jax.jit
    def model_fn(x):
        kw = {}
        for k, v in extras.items():
            reps = x.shape[0] // v.shape[0]
            kw[k] = jnp.tile(v, (reps,) + (1,) * (v.ndim - 1)) \
                if reps > 1 else v
        return forward(params, x, cfg, **kw)[0]

    return model_fn


def generate(rng, model_fn: Callable, prompt: jnp.ndarray,
             cfg: ModelConfig, dcfg: DecodeConfig,
             strategy: Optional[str] = None) -> tuple:
    """Deprecated: use ``Decoder(model_fn, cfg, dcfg).generate(...)``.

    Decode ``gen_length`` tokens after ``prompt`` (B, Lp).  Returns
    (tokens (B, Lp+gen), SampleStats).  Token-for-token equivalent to the
    Decoder path (it *is* the Decoder path) and shares its runner cache.
    """
    warnings.warn("repro.core.generate() is deprecated; use "
                  "Decoder(model_fn, cfg, dcfg).generate(rng, prompt)",
                  DeprecationWarning, stacklevel=2)
    return Decoder(model_fn, cfg, dcfg).generate(rng, prompt,
                                                 strategy=strategy)


def generate_cached(rng, params, prompt: jnp.ndarray, cfg: ModelConfig,
                    dcfg: DecodeConfig, strategy: Optional[str] = None,
                    enc_embeds=None, state_dtype=None) -> tuple:
    """Deprecated: use ``Decoder(params, cfg, dcfg).generate_cached(...)``.

    Frozen-prefix cached decoding (DESIGN.md §3).  Unlike the seed-era
    implementation, window forwards and the fused block runner come from
    the params-keyed cross-call cache — repeat calls compile nothing.
    """
    warnings.warn("repro.core.generate_cached() is deprecated; use "
                  "Decoder(params, cfg, dcfg).generate_cached(rng, prompt)",
                  DeprecationWarning, stacklevel=2)
    return Decoder(params, cfg, dcfg).generate_cached(
        rng, prompt, strategy=strategy, enc_embeds=enc_embeds,
        state_dtype=state_dtype)
