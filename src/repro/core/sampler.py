"""The semi-autoregressive block sampler (paper §5.1 pipeline).

Generation length 256 in blocks of 64 (defaults from the paper): the answer
region is decoded block by block left-to-right, but *within* a block the
decoding order is free — that is where the strategy (heuristic / EB / WINO /
FDM / FDM-A) earns its keep.

The intra-block step loop is device-resident by default
(``DecodeConfig.fused_loop``): ``core/loop.py`` compiles each block's
denoising steps into a single ``lax.while_loop`` program with zero per-step
host syncs; fixed shapes throughout keep it at exactly one compilation per
(strategy × shape).  ``fused_loop=False`` falls back to the legacy host
step loop (one dispatch + one scalar sync + one host RNG split per step) —
the debugging / A/B path, measured by ``benchmarks/loop_overhead.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.masking import fully_masked
from repro.core.strategies import get_strategy


def make_model_fn(params, cfg: ModelConfig, **extras) -> Callable:
    """tokens (B', L) -> logits, with conditioning inputs (enc_embeds /
    patch_embeds) tiled to match B'.  FDM folds its K candidates into the
    batch axis (B' = K·B ordered candidate-major), so the conditioning
    must replicate candidate-major too — ``jnp.tile`` does exactly that.
    """
    import jax.numpy as jnp
    from repro.models.model import forward

    @jax.jit
    def model_fn(x):
        kw = {}
        for k, v in extras.items():
            reps = x.shape[0] // v.shape[0]
            kw[k] = jnp.tile(v, (reps,) + (1,) * (v.ndim - 1)) \
                if reps > 1 else v
        return forward(params, x, cfg, **kw)[0]

    return model_fn


@dataclass
class SampleStats:
    steps: int = 0
    forward_equivalents: int = 0   # batched-forward count (K-search = K)
    wall_time: float = 0.0
    tokens_generated: int = 0
    phase_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def tps(self) -> float:
        return self.tokens_generated / max(self.wall_time, 1e-9)

    @property
    def tokens_per_forward(self) -> float:
        return self.tokens_generated / max(self.forward_equivalents, 1)


def generate(rng, model_fn: Callable, prompt: jnp.ndarray,
             cfg: ModelConfig, dcfg: DecodeConfig,
             strategy: Optional[str] = None) -> tuple:
    """Decode ``gen_length`` tokens after ``prompt`` (B, Lp).

    Returns (tokens (B, Lp+gen), SampleStats).
    """
    strategy = strategy or dcfg.strategy
    step_fn = get_strategy(strategy)
    b, lp = prompt.shape
    gen, bs = dcfg.gen_length, dcfg.block_size
    assert gen % bs == 0
    num_blocks = gen // bs
    steps_per_block = max(dcfg.steps // num_blocks, 1)
    n_per_step = max(bs // steps_per_block, 1)     # heuristic commit width

    x = fully_masked(cfg, prompt, gen)
    stats = SampleStats(tokens_generated=b * gen)
    t0 = time.perf_counter()

    if dcfg.fused_loop:
        from repro.core.loop import block_runner
        run = block_runner(model_fn, strategy, cfg, dcfg, n_per_step)
        steps = jnp.zeros((), jnp.int32)
        fwd = jnp.zeros((), jnp.float32)
        for blk in range(num_blocks):
            x, rng, steps, fwd = run(x, rng, jnp.int32(lp + blk * bs),
                                     steps, fwd)
        # one sync for the whole decode: canvas + both stats counters
        x.block_until_ready()
        stats.steps = int(jax.device_get(steps))
        stats.forward_equivalents = float(jax.device_get(fwd))
    else:
        for blk in range(num_blocks):
            lo, hi = lp + blk * bs, lp + (blk + 1) * bs
            in_block = (jnp.arange(x.shape[1]) >= lo) & \
                (jnp.arange(x.shape[1]) < hi)
            # guard: a strategy always commits ≥1 token/example/step, so a
            # block can never need more than B-agnostic bs steps
            for it in range(bs * 4):
                active = in_block[None, :] & (x == cfg.mask_token_id)
                if not bool(jax.device_get(jnp.any(active))):
                    break
                rng, step_rng = jax.random.split(rng)
                x, fwd = step_fn(step_rng, x, active, model_fn, cfg, dcfg,
                                 n_per_step)
                stats.steps += 1
                stats.forward_equivalents += fwd
        x.block_until_ready()
    stats.wall_time = time.perf_counter() - t0
    return x, stats


def generate_cached(rng, params, prompt: jnp.ndarray, cfg: ModelConfig,
                    dcfg: DecodeConfig, strategy: Optional[str] = None,
                    enc_embeds=None, state_dtype=None) -> tuple:
    """Frozen-prefix cached decoding (the Fast-dLLM-style acceleration the
    paper's related work ships, §3).

    Committed blocks live in per-layer KV caches / recurrent states; each
    denoising step forwards only the LIVE WINDOW — the active block plus
    the still-masked future blocks — against the frozen prefix.  (A
    block-only window was measured to collapse quality 81% → 19% on the
    sort testbed: masked-diffusion models read the future mask tokens as
    a length/position signal, so the suffix must stay live; this is the
    "prefix cache" half of Fast-dLLM's DualCache.)  The single remaining
    approximation is the standard frozen-prefix one (DESIGN.md §3); per-
    step cost drops from O(L²) toward O((L−prefix)·L) as blocks commit.
    """
    import functools
    import jax.numpy as jnp  # noqa: F811

    from repro.models.model import (encode, forward_window,
                                    init_decode_state, set_valid_length)

    strategy = strategy or dcfg.strategy
    step_fn = get_strategy(strategy, fused=dcfg.fused_loop)
    b, lp = prompt.shape
    gen, bs = dcfg.gen_length, dcfg.block_size
    assert gen % bs == 0
    num_blocks = gen // bs
    steps_per_block = max(dcfg.steps // num_blocks, 1)
    n_per_step = max(bs // steps_per_block, 1)
    total = lp + gen
    dtype = state_dtype or jnp.float32

    enc_out = None
    if cfg.is_encdec and enc_embeds is not None:
        enc_out = encode(params, enc_embeds, cfg)
    state = init_decode_state(cfg, b, total, dtype, enc_out=enc_out,
                              valid_length=0)

    win_fwd = jax.jit(functools.partial(forward_window, params, cfg=cfg))
    extend_kv = jax.jit(functools.partial(forward_window, params, cfg=cfg,
                                          extend="kv"))
    extend_rec = jax.jit(functools.partial(forward_window, params, cfg=cfg,
                                           extend="recurrent"))

    def tile_state(st: "DecodeState", reps: int):
        if reps == 1:
            return st
        ls = jax.tree.map(
            lambda a: jnp.tile(a, (1, reps) + (1,) * (a.ndim - 2))
            if a.ndim >= 2 else a, st.layer_states)
        eo = None if st.enc_out is None else \
            jnp.tile(st.enc_out, (reps, 1, 1))
        from repro.models.model import DecodeState
        return DecodeState(layer_states=ls, enc_out=eo)

    # prefill: k/v of the prompt must be encoded WITH the masked answer
    # region visible (bidirectional context carries the length signal), so
    # the kv-extend runs over [prompt | masks] and the valid length is
    # reset to the prompt; causal recurrent states advance over the
    # prompt only (they never see the future by construction).
    stats = SampleStats(tokens_generated=b * gen)
    t0 = time.perf_counter()
    x = fully_masked(cfg, prompt, gen)
    all_pos = jnp.arange(total, dtype=jnp.int32)[None].repeat(b, 0)
    _, state = extend_kv(x, all_pos, state)
    state = set_valid_length(state, lp)
    prompt_pos = all_pos[:, :lp]
    _, state = extend_rec(prompt, prompt_pos, state)
    stats.forward_equivalents += 1
    steps_c = jnp.zeros((), jnp.int32)
    fwd_c = jnp.zeros((), jnp.float32)
    for blk in range(num_blocks):
        lo, hi = lp + blk * bs, lp + (blk + 1) * bs
        # live window = active block + still-masked future blocks
        win_pos = jnp.arange(lo, total, dtype=jnp.int32)[None].repeat(b, 0)
        blk_pos = jnp.arange(lo, hi, dtype=jnp.int32)[None].repeat(b, 0)
        wlen = total - lo
        in_block = jnp.arange(wlen) < bs

        if dcfg.fused_loop:
            # fuse everything inside the block: the per-block host boundary
            # stays (KV extension below re-shapes the state) but the whole
            # denoising loop is one compiled while_loop program, with the
            # decode state a traced argument rather than a baked constant.
            # Like the seed's per-call win_fwd jits, run_blk recompiles per
            # generate_cached call (window shapes also differ per block) —
            # a params-keyed cross-call runner cache is a ROADMAP item.
            from repro.core.loop import drive_block

            @jax.jit
            def run_blk(x_win, key, st, steps, fwd, _pos=win_pos,
                        _in=in_block, _scale=wlen / (total - lp)):
                def mfn(w):
                    reps = w.shape[0] // b
                    p = jnp.tile(_pos, (reps, 1)) if reps > 1 else _pos
                    return win_fwd(w, p, tile_state(st, reps))[0]
                return drive_block(step_fn, mfn, cfg, dcfg, n_per_step,
                                   x_win, key, _in, steps, fwd,
                                   fwd_scale=_scale)

            new_win, rng, steps_c, fwd_c = run_blk(x[:, lo:], rng, state,
                                                   steps_c, fwd_c)
            x = jax.lax.dynamic_update_slice_in_dim(x, new_win, lo, axis=1)
        else:
            cur_state = state

            def model_fn(w):
                reps = w.shape[0] // b
                pos = jnp.tile(win_pos, (reps, 1)) if reps > 1 else win_pos
                return win_fwd(w, pos, tile_state(cur_state, reps))[0]

            for it in range(bs * 4):
                x_win = x[:, lo:]
                active = in_block[None, :] & (x_win == cfg.mask_token_id)
                if not bool(jax.device_get(jnp.any(active))):
                    break
                rng, step_rng = jax.random.split(rng)
                new_win, fwd = step_fn(step_rng, x_win, active, model_fn,
                                       cfg, dcfg, n_per_step)
                x = jax.lax.dynamic_update_slice_in_dim(x, new_win, lo,
                                                        axis=1)
                stats.steps += 1
                stats.forward_equivalents += fwd * wlen / (total - lp)
        # block committed: k/v from the live window (future context kept),
        # then valid length clipped to the committed block; recurrent
        # states advance over the block only
        _, state = extend_kv(x[:, lo:], win_pos, state)
        state = set_valid_length(state, hi)
        _, state = extend_rec(x[:, lo:hi], blk_pos, state)
        stats.forward_equivalents += 1
    x.block_until_ready()
    if dcfg.fused_loop:
        stats.steps = int(jax.device_get(steps_c))
        stats.forward_equivalents += float(jax.device_get(fwd_c))
    stats.wall_time = time.perf_counter() - t0
    return x, stats
