"""Device-resident block decoding: the fused intra-block step driver.

The legacy sampler runs the denoising loop on host — per step it pays a
jitted dispatch, a host RNG split, and a blocking scalar sync
(``bool(device_get(any(active)))``).  On small/medium models that makes the
decode loop dispatch-bound, not FLOP-bound, hiding exactly the efficiency
gains FDM/FDM-A exist to demonstrate (Table 3 / §5.3).

This module fuses a whole block into ONE compiled XLA program: a
``jax.lax.while_loop`` whose carry is ``(x, rng, steps, fwd, carry)`` —

  x      (B, L) int32   — the token canvas (or the live window, cached path)
  rng    PRNG key       — split *inside* the carry, one split per executed
                          step, the same stream the host loop consumes (so
                          fused and host decoding are bit-identical)
  steps  () int32       — device step counter
  fwd    () float32     — device forward-equivalents counter (f32 because
                          the cached path pro-rates by window length)
  carry  pytree         — the strategy's own state (``Strategy.init_carry``;
                          ``()`` for the stateless builtins)

Termination is "no active masks left in the block" plus a ``block_size·4``
safety cap matching the host loop's guard.  The step comes from
``Strategy.fused_step`` — each strategy declares its own trace-safe form
(FDM-A's host early-out is a ``lax.cond`` there), so a block executes with
ZERO host round-trips; the host touches the device once per block to hand
over the carry, and the stats counters come back in a single
``device_get`` at the end of decode.

``drive_request`` goes one level further: the OUTER block loop becomes a
``lax.scan`` over block indices, so a plain-path decode is ONE compiled
dispatch per request — the block start offsets and per-step commit-width
schedules are scanned arrays, the strategy carry rides the scan carry
across blocks, and per-block streaming survives as an *ordered*
``jax.experimental.io_callback`` (see DESIGN.md "one dispatch per
request").  ``DecodeConfig.fused_blocks=False`` keeps the per-block host
driver for debugging; the cached path always uses it (its window shapes
are block-varying).

Runner construction and cross-call caching live in ``core/decoder.py``:
the ``Decoder`` owns a params-keyed, weak-referenced runner cache so
repeat decodes — the serving engine, benchmark warmup+measure pairs —
reuse one compilation per strategy × shape without pinning model weights
in an ``lru_cache``.  ``block_runner`` below survives as a deprecation
shim over that cache.

When is the host loop still right?  Set ``DecodeConfig.fused_loop=False``
to step-debug a strategy (prints / pdb inside step functions), to inspect
per-step canvases, or on backends where long while_loop bodies compile
slowly; ``benchmarks/loop_overhead.py`` A/Bs the two drivers.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.strategies import as_strategy


def drive_block(strategy, model_fn: Callable, cfg: ModelConfig,
                dcfg: DecodeConfig, n_per_step, x: jnp.ndarray,
                rng, in_block: jnp.ndarray, steps, fwd, carry=(),
                fwd_scale=1.0):
    """Run one block's denoising steps as a single ``lax.while_loop``.

    Traceable building block (call under jit): ``strategy`` is a
    ``Strategy`` (a registered name or a legacy step callable is coerced);
    ``in_block`` is a (L,) bool marking the current block's columns of
    ``x``; ``steps``/``fwd`` are the running device counters and ``carry``
    the strategy's own state, all returned advanced.  ``fwd_scale``
    pro-rates forward-equivalents for the cached path (window / full-seq
    cost ratio).  Returns ``(x, rng, steps, fwd, carry)``.

    ``n_per_step`` is the commit-width hand the strategy is dealt each
    step: either a scalar (constant width) or a ``(S,)`` int32 *schedule*
    indexed by the step-within-block (``Decoder._geometry`` emits one that
    spreads ``dcfg.steps`` exactly across blocks, remainders included).
    The index clamps to the last entry, so overrunning the schedule stays
    safe — which now matters for more than width-ignoring strategies:
    revoking strategies (``wino_r``) UN-commit tokens, so a block can
    legitimately need more steps than its schedule budgeted.
    ``_geometry`` pads schedule rows with their final width (never zero)
    so those overrun steps keep a progress guarantee, and the
    ``block_size·4`` cap plus the revocation budget bound the overrun.
    """
    strategy = as_strategy(strategy)
    mask_id = cfg.mask_token_id
    max_steps = dcfg.block_size * 4           # matches the host-loop guard
    sched = jnp.asarray(n_per_step, jnp.int32)
    start = steps
    # block-entry hook (traceable): carry-ful strategies reset the state
    # that must not leak across a block boundary (WINO revocation drops
    # its pending set — a streamed block can never be re-opened)
    carry = strategy.begin_block(carry, x, in_block)

    def active_of(canvas):
        return in_block[None, :] & (canvas == mask_id)

    def cond(c):
        canvas, _, s, _, _ = c
        return jnp.any(active_of(canvas)) & (s - start < max_steps)

    def body(c):
        canvas, key, s, f, sc = c
        key, step_key = jax.random.split(key)
        n = sched if sched.ndim == 0 else \
            sched[jnp.minimum(s - start, sched.shape[0] - 1)]
        new_canvas, new_sc, df = strategy.fused_step(
            step_key, sc, canvas, active_of(canvas), model_fn, cfg, dcfg,
            n)
        return (new_canvas, key, s + 1,
                f + jnp.asarray(df, jnp.float32) * fwd_scale, new_sc)

    return jax.lax.while_loop(cond, body, (x, rng, steps, fwd, carry))


def drive_request(strategy, model_fn: Callable, cfg: ModelConfig,
                  dcfg: DecodeConfig, x: jnp.ndarray, rng,
                  block_los: jnp.ndarray, schedules: jnp.ndarray,
                  steps, fwd, carry=(),
                  emit: Optional[Callable] = None):
    """Run the WHOLE request — every semi-AR block — as one ``lax.scan``.

    Traceable building block (call under jit).  ``block_los`` is the
    ``(num_blocks,)`` int32 array of block start columns and ``schedules``
    the ``(num_blocks, S)`` per-block commit-width schedules; both are
    traced, so one executable serves every prompt length and step budget
    of the same shape.  Each scan iteration computes ``in_block`` from the
    scanned ``lo``, runs ``drive_block``'s ``while_loop``, and — when
    ``emit`` is given — fires ``emit(block_index, lo, hi, canvas)`` as an
    *ordered* ``io_callback``, so streaming observers see blocks in commit
    order without breaking the single dispatch.  The strategy carry rides
    the scan carry across blocks.  Returns ``(x, rng, steps, fwd, carry)``
    exactly like ``drive_block``; the decode math is bit-identical to
    driving the blocks from host (parity-tested for all strategies).
    """
    strategy = as_strategy(strategy)
    bs = dcfg.block_size
    pos = jnp.arange(x.shape[1])

    def scan_body(c, xs):
        blk, lo, sched = xs
        canvas, key, s, f, sc = c
        in_block = (pos >= lo) & (pos < lo + bs)
        canvas, key, s, f, sc = drive_block(
            strategy, model_fn, cfg, dcfg, sched, canvas, key, in_block,
            s, f, sc)
        if emit is not None:
            io_callback(emit, None, blk, lo, lo + bs, canvas, ordered=True)
        return (canvas, key, s, f, sc), None

    num_blocks = block_los.shape[0]
    xs = (jnp.arange(num_blocks, dtype=jnp.int32),
          jnp.asarray(block_los, jnp.int32),
          jnp.asarray(schedules, jnp.int32))
    out, _ = jax.lax.scan(scan_body, (x, rng, steps, fwd, carry), xs)
    return out


def block_runner(model_fn: Callable, strategy: str, cfg: ModelConfig,
                 dcfg: DecodeConfig, n_per_step: int) -> Callable:
    """Deprecated pre-Decoder entry point, kept for one release.

    Returns ``run(x, rng, lo, steps, fwd) -> (x, rng, steps, fwd)`` with
    ``lo`` (traced int32) the block's start column.  Backed by the
    ``Decoder`` runner cache, so it shares compilations with the new API
    — and, unlike the old ``lru_cache``, drops them when ``model_fn`` is
    garbage-collected instead of pinning it forever.
    """
    from repro.core.decoder import Decoder
    from repro.core.strategies import resolve_strategy

    strat = resolve_strategy(strategy)
    run6 = Decoder(model_fn, cfg, dcfg)._plain_runner(strat)
    carry0 = strat.init_carry(cfg, dcfg)
    # constant commit width: a length-1 schedule (the step index clamps)
    sched = jnp.full((1,), n_per_step, jnp.int32)

    # the cache only weakrefs model_fn; the returned runner must pin it
    # (matching the seed contract — callers pass the jit expression inline)
    def run(x, rng, lo, steps, fwd, _model_fn=model_fn):
        x, rng, steps, fwd, _ = run6(x, rng, lo, sched, steps, fwd, carry0)
        return x, rng, steps, fwd

    return run
