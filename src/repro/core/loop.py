"""Device-resident block decoding: the fused intra-block step driver.

The legacy sampler runs the denoising loop on host — per step it pays a
jitted dispatch, a host RNG split, and a blocking scalar sync
(``bool(device_get(any(active)))``).  On small/medium models that makes the
decode loop dispatch-bound, not FLOP-bound, hiding exactly the efficiency
gains FDM/FDM-A exist to demonstrate (Table 3 / §5.3).

This module fuses a whole block into ONE compiled XLA program: a
``jax.lax.while_loop`` whose carry is ``(x, rng, steps, fwd, carry)`` —

  x      (B, L) int32   — the token canvas (or the live window, cached path)
  rng    PRNG key       — split *inside* the carry, one split per executed
                          step, the same stream the host loop consumes (so
                          fused and host decoding are bit-identical)
  steps  () int32       — device step counter
  fwd    () float32     — device forward-equivalents counter (f32 because
                          the cached path pro-rates by window length)
  carry  pytree         — the strategy's own state (``Strategy.init_carry``;
                          ``()`` for the stateless builtins)

Termination is "no active masks left in the block" plus a ``block_size·4``
safety cap matching the host loop's guard.  The step comes from
``Strategy.fused_step`` — each strategy declares its own trace-safe form
(FDM-A's host early-out is a ``lax.cond`` there), so a block executes with
ZERO host round-trips; the host touches the device once per block to hand
over the carry, and the stats counters come back in a single
``device_get`` at the end of decode.

``drive_request`` goes one level further: the OUTER block loop becomes a
``lax.scan`` over block indices, so a plain-path decode is ONE compiled
dispatch per request — the block start offsets and per-step commit-width
schedules are scanned arrays, the strategy carry rides the scan carry
across blocks, and per-block streaming survives as an *ordered*
``jax.experimental.io_callback`` (see DESIGN.md "one dispatch per
request").  ``DecodeConfig.fused_blocks=False`` keeps the per-block host
driver for debugging and block-grain scheduling.

``drive_request_cached`` is the KV-cached variant of the same scan
(``DecodeConfig.cache_policy`` ∈ ``{prefix, dual}``, DESIGN.md "The KV
cache"): the fixed-shape cache captured by ``capture_cache`` rides the
scan carry, each block decodes a fixed-width live window against it
(``drive_cached_block``), and the block boundary optionally refreshes
the cache with one full capture forward — all inside the single
dispatch.  Every window shape is static (``prefix``: the whole
generation region at a static offset; ``dual``: one block at a traced
offset), which is what lets the cached path ride ``lax.scan`` at all —
the legacy shrinking-window path could not.

Runner construction and cross-call caching live in ``core/decoder.py``:
the ``Decoder`` owns a params-keyed, weak-referenced runner cache so
repeat decodes — the serving engine, benchmark warmup+measure pairs —
reuse one compilation per strategy × shape × cache policy without
pinning model weights in an ``lru_cache``.

When is the host loop still right?  Set ``DecodeConfig.fused_loop=False``
to step-debug a strategy (prints / pdb inside step functions), to inspect
per-step canvases, or on backends where long while_loop bodies compile
slowly; ``benchmarks/loop_overhead.py`` A/Bs the two drivers.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.strategies import as_strategy


def drive_block(strategy, model_fn: Callable, cfg: ModelConfig,
                dcfg: DecodeConfig, n_per_step, x: jnp.ndarray,
                rng, in_block: jnp.ndarray, steps, fwd, carry=(),
                fwd_scale=1.0):
    """Run one block's denoising steps as a single ``lax.while_loop``.

    Traceable building block (call under jit): ``strategy`` is a
    ``Strategy`` (a registered name or a legacy step callable is coerced);
    ``in_block`` is a (L,) bool marking the current block's columns of
    ``x``; ``steps``/``fwd`` are the running device counters and ``carry``
    the strategy's own state, all returned advanced.  ``fwd_scale``
    pro-rates forward-equivalents for the cached path (window / full-seq
    cost ratio).  Returns ``(x, rng, steps, fwd, carry)``.

    ``n_per_step`` is the commit-width hand the strategy is dealt each
    step: either a scalar (constant width) or a ``(S,)`` int32 *schedule*
    indexed by the step-within-block (``Decoder._geometry`` emits one that
    spreads ``dcfg.steps`` exactly across blocks, remainders included).
    The index clamps to the last entry, so overrunning the schedule stays
    safe — which now matters for more than width-ignoring strategies:
    revoking strategies (``wino_r``) UN-commit tokens, so a block can
    legitimately need more steps than its schedule budgeted.
    ``_geometry`` pads schedule rows with their final width (never zero)
    so those overrun steps keep a progress guarantee, and the
    ``block_size·4`` cap plus the revocation budget bound the overrun.
    """
    strategy = as_strategy(strategy)
    mask_id = cfg.mask_token_id
    max_steps = dcfg.block_size * 4           # matches the host-loop guard
    sched = jnp.asarray(n_per_step, jnp.int32)
    start = steps
    # block-entry hook (traceable): carry-ful strategies reset the state
    # that must not leak across a block boundary (WINO revocation drops
    # its pending set — a streamed block can never be re-opened)
    carry = strategy.begin_block(carry, x, in_block)

    def active_of(canvas):
        return in_block[None, :] & (canvas == mask_id)

    def cond(c):
        canvas, _, s, _, _ = c
        return jnp.any(active_of(canvas)) & (s - start < max_steps)

    def body(c):
        canvas, key, s, f, sc = c
        key, step_key = jax.random.split(key)
        n = sched if sched.ndim == 0 else \
            sched[jnp.minimum(s - start, sched.shape[0] - 1)]
        new_canvas, new_sc, df = strategy.fused_step(
            step_key, sc, canvas, active_of(canvas), model_fn, cfg, dcfg,
            n)
        return (new_canvas, key, s + 1,
                f + jnp.asarray(df, jnp.float32) * fwd_scale, new_sc)

    return jax.lax.while_loop(cond, body, (x, rng, steps, fwd, carry))


def drive_request(strategy, model_fn: Callable, cfg: ModelConfig,
                  dcfg: DecodeConfig, x: jnp.ndarray, rng,
                  block_los: jnp.ndarray, schedules: jnp.ndarray,
                  steps, fwd, carry=(),
                  emit: Optional[Callable] = None):
    """Run the WHOLE request — every semi-AR block — as one ``lax.scan``.

    Traceable building block (call under jit).  ``block_los`` is the
    ``(num_blocks,)`` int32 array of block start columns and ``schedules``
    the ``(num_blocks, S)`` per-block commit-width schedules; both are
    traced, so one executable serves every prompt length and step budget
    of the same shape.  Each scan iteration computes ``in_block`` from the
    scanned ``lo``, runs ``drive_block``'s ``while_loop``, and — when
    ``emit`` is given — fires ``emit(block_index, lo, hi, canvas)`` as an
    *ordered* ``io_callback``, so streaming observers see blocks in commit
    order without breaking the single dispatch.  The strategy carry rides
    the scan carry across blocks.  Returns ``(x, rng, steps, fwd, carry)``
    exactly like ``drive_block``; the decode math is bit-identical to
    driving the blocks from host (parity-tested for all strategies).
    """
    strategy = as_strategy(strategy)
    bs = dcfg.block_size
    pos = jnp.arange(x.shape[1])

    def scan_body(c, xs):
        blk, lo, sched = xs
        canvas, key, s, f, sc = c
        in_block = (pos >= lo) & (pos < lo + bs)
        canvas, key, s, f, sc = drive_block(
            strategy, model_fn, cfg, dcfg, sched, canvas, key, in_block,
            s, f, sc)
        if emit is not None:
            io_callback(emit, None, blk, lo, lo + bs, canvas, ordered=True)
        return (canvas, key, s, f, sc), None

    num_blocks = block_los.shape[0]
    xs = (jnp.arange(num_blocks, dtype=jnp.int32),
          jnp.asarray(block_los, jnp.int32),
          jnp.asarray(schedules, jnp.int32))
    out, _ = jax.lax.scan(scan_body, (x, rng, steps, fwd, carry), xs)
    return out


def carry_window(strategy, carry, lo, width: int):
    """Cached path: slice a positional carry's per-column leaves to the
    live window ``[:, lo:lo+width]``, exactly like the canvas itself
    (``lo`` may be traced).  Carries of strategies without
    ``positional_carry`` pass through whole."""
    strategy = as_strategy(strategy)
    if not strategy.positional_carry:
        return carry
    pos, glob = carry
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, lo, width, axis=1),
        pos), glob


def carry_unwindow(strategy, carry_full, carry_win, lo):
    """Write a block's updated window carry back into the full-canvas
    positional leaves (inverse of ``carry_window``)."""
    strategy = as_strategy(strategy)
    if not strategy.positional_carry:
        return carry_win
    pos_full, _ = carry_full
    pos_win, glob = carry_win
    pos = jax.tree.map(
        lambda full, win: jax.lax.dynamic_update_slice_in_dim(
            full, win, lo, axis=1), pos_full, pos_win)
    return pos, glob


def window_geometry(dcfg: DecodeConfig, total: int):
    """(window width, static window start or None) for a cache policy.

    ``prefix`` keeps the WHOLE generation region live — fixed width
    ``gen_length`` at the static offset ``total - gen_length`` (committed
    blocks are re-scored every step, so decoding within the generation is
    exact; only the prompt's deep-layer K/V are frozen).  ``dual``
    (Fast-dLLM) keeps only the active block live — fixed width
    ``block_size`` at the traced block offset; prompt, committed blocks
    AND the masked suffix are all served from the cache (the suffix K/V
    go stale within a block — the documented approximation)."""
    if dcfg.cache_policy == "prefix":
        return dcfg.gen_length, total - dcfg.gen_length
    return dcfg.block_size, None


def drive_cached_block(strategy, cached_fn: Callable, cfg: ModelConfig,
                       dcfg: DecodeConfig, x: jnp.ndarray, rng, lo,
                       sched, steps, fwd, carry, state):
    """One block of KV-cached decoding (traceable building block).

    Slices the policy's live window out of the canvas, runs the block's
    denoising ``while_loop`` against the fixed-shape cache ``state``
    (``cached_fn(x_win, win_lo, state) -> logits``, read-only w.r.t. the
    cache), and writes the window back.  Forward-equivalents are
    pro-rated by ``window/total``.  Returns ``(x, rng, steps, fwd,
    carry)``; ``state`` is not advanced — refreshes are the caller's
    (block-boundary) concern.
    """
    strategy = as_strategy(strategy)
    bs = dcfg.block_size
    total = x.shape[1]
    win, static_lo = window_geometry(dcfg, total)
    win_lo = jnp.int32(static_lo) if static_lo is not None else lo
    x_win = jax.lax.dynamic_slice_in_dim(x, win_lo, win, axis=1)
    wpos = win_lo + jnp.arange(win)
    in_block = (wpos >= lo) & (wpos < lo + bs)
    wcarry = carry_window(strategy, carry, win_lo, win)
    x_win, rng, steps, fwd, wcarry = drive_block(
        strategy, lambda w: cached_fn(w, win_lo, state), cfg, dcfg, sched,
        x_win, rng, in_block, steps, fwd, wcarry, fwd_scale=win / total)
    x = jax.lax.dynamic_update_slice_in_dim(x, x_win, win_lo, axis=1)
    carry = carry_unwindow(strategy, carry, wcarry, win_lo)
    return x, rng, steps, fwd, carry


def drive_request_cached(strategy, cached_fn: Callable,
                         refresh_fn: Callable, cfg: ModelConfig,
                         dcfg: DecodeConfig, x: jnp.ndarray, rng,
                         block_los: jnp.ndarray, schedules: jnp.ndarray,
                         steps, fwd, carry=(),
                         emit: Optional[Callable] = None):
    """Whole-request KV-cached decoding as one ``lax.scan``.

    ``refresh_fn(canvas) -> state`` is the full-forward cache capture
    (``models.model.capture_cache`` under the hood): it runs once up
    front as the prefill and — when ``dcfg.cache_refresh == 'block'`` —
    again at every later block boundary, inside the scan via
    ``lax.cond``, so the whole request stays a single dispatch.  Each
    refresh costs one forward-equivalent; windowed steps cost
    ``window/total``.  The cache state rides the scan carry as ordinary
    traced data (never a baked const — ANA103 checks the trace).
    Returns ``(x, rng, steps, fwd, carry)`` exactly like
    ``drive_request``.
    """
    strategy = as_strategy(strategy)
    bs = dcfg.block_size
    refresh_each = dcfg.cache_refresh == "block"

    state = refresh_fn(x)                     # prefill = block-0 refresh
    fwd = fwd + jnp.float32(1.0)

    def scan_body(c, xs):
        blk, lo, sched = xs
        canvas, key, s, f, sc, st = c
        if refresh_each:
            st = jax.lax.cond(blk > 0, refresh_fn, lambda cv: st, canvas)
            f = f + jnp.where(blk > 0, jnp.float32(1.0), jnp.float32(0.0))
        canvas, key, s, f, sc = drive_cached_block(
            strategy, cached_fn, cfg, dcfg, canvas, key, lo, sched,
            s, f, sc, st)
        if emit is not None:
            io_callback(emit, None, blk, lo, lo + bs, canvas, ordered=True)
        return (canvas, key, s, f, sc, st), None

    num_blocks = block_los.shape[0]
    xs = (jnp.arange(num_blocks, dtype=jnp.int32),
          jnp.asarray(block_los, jnp.int32),
          jnp.asarray(schedules, jnp.int32))
    (x, rng, steps, fwd, carry, _), _ = jax.lax.scan(
        scan_body, (x, rng, steps, fwd, carry, state), xs)
    return x, rng, steps, fwd, carry
