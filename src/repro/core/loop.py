"""Device-resident block decoding: the fused intra-block step driver.

The legacy sampler runs the denoising loop on host — per step it pays a
jitted dispatch, a host RNG split, and a blocking scalar sync
(``bool(device_get(any(active)))``).  On small/medium models that makes the
decode loop dispatch-bound, not FLOP-bound, hiding exactly the efficiency
gains FDM/FDM-A exist to demonstrate (Table 3 / §5.3).

This module fuses a whole block into ONE compiled XLA program: a
``jax.lax.while_loop`` whose carry is ``(x, rng, steps, fwd, carry)`` —

  x      (B, L) int32   — the token canvas (or the live window, cached path)
  rng    PRNG key       — split *inside* the carry, one split per executed
                          step, the same stream the host loop consumes (so
                          fused and host decoding are bit-identical)
  steps  () int32       — device step counter
  fwd    () float32     — device forward-equivalents counter (f32 because
                          the cached path pro-rates by window length)
  carry  pytree         — the strategy's own state (``Strategy.init_carry``;
                          ``()`` for the stateless builtins)

Termination is "no active masks left in the block" plus a ``block_size·4``
safety cap matching the host loop's guard.  The step comes from
``Strategy.fused_step`` — each strategy declares its own trace-safe form
(FDM-A's host early-out is a ``lax.cond`` there), so a block executes with
ZERO host round-trips; the host touches the device once per block to hand
over the carry, and the stats counters come back in a single
``device_get`` at the end of decode.

Runner construction and cross-call caching live in ``core/decoder.py``:
the ``Decoder`` owns a params-keyed, weak-referenced runner cache so
repeat decodes — the serving engine, benchmark warmup+measure pairs —
reuse one compilation per strategy × shape without pinning model weights
in an ``lru_cache``.  ``block_runner`` below survives as a deprecation
shim over that cache.

When is the host loop still right?  Set ``DecodeConfig.fused_loop=False``
to step-debug a strategy (prints / pdb inside step functions), to inspect
per-step canvases, or on backends where long while_loop bodies compile
slowly; ``benchmarks/loop_overhead.py`` A/Bs the two drivers.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.strategies import Strategy, as_strategy


def drive_block(strategy, model_fn: Callable, cfg: ModelConfig,
                dcfg: DecodeConfig, n_per_step: int, x: jnp.ndarray,
                rng, in_block: jnp.ndarray, steps, fwd, carry=(),
                fwd_scale=1.0):
    """Run one block's denoising steps as a single ``lax.while_loop``.

    Traceable building block (call under jit): ``strategy`` is a
    ``Strategy`` (a registered name or a legacy step callable is coerced);
    ``in_block`` is a (L,) bool marking the current block's columns of
    ``x``; ``steps``/``fwd`` are the running device counters and ``carry``
    the strategy's own state, all returned advanced.  ``fwd_scale``
    pro-rates forward-equivalents for the cached path (window / full-seq
    cost ratio).  Returns ``(x, rng, steps, fwd, carry)``.
    """
    strategy = as_strategy(strategy)
    mask_id = cfg.mask_token_id
    max_steps = dcfg.block_size * 4           # matches the host-loop guard
    start = steps

    def active_of(canvas):
        return in_block[None, :] & (canvas == mask_id)

    def cond(c):
        canvas, _, s, _, _ = c
        return jnp.any(active_of(canvas)) & (s - start < max_steps)

    def body(c):
        canvas, key, s, f, sc = c
        key, step_key = jax.random.split(key)
        new_canvas, new_sc, df = strategy.fused_step(
            step_key, sc, canvas, active_of(canvas), model_fn, cfg, dcfg,
            n_per_step)
        return (new_canvas, key, s + 1,
                f + jnp.asarray(df, jnp.float32) * fwd_scale, new_sc)

    return jax.lax.while_loop(cond, body, (x, rng, steps, fwd, carry))


def block_runner(model_fn: Callable, strategy: str, cfg: ModelConfig,
                 dcfg: DecodeConfig, n_per_step: int) -> Callable:
    """Deprecated pre-Decoder entry point, kept for one release.

    Returns ``run(x, rng, lo, steps, fwd) -> (x, rng, steps, fwd)`` with
    ``lo`` (traced int32) the block's start column.  Backed by the
    ``Decoder`` runner cache, so it shares compilations with the new API
    — and, unlike the old ``lru_cache``, drops them when ``model_fn`` is
    garbage-collected instead of pinning it forever.
    """
    from repro.core.decoder import Decoder
    from repro.core.strategies import resolve_strategy

    strat = resolve_strategy(strategy)
    run5 = Decoder(model_fn, cfg, dcfg)._plain_runner(strat, n_per_step)
    carry0 = strat.init_carry(cfg, dcfg)

    # the cache only weakrefs model_fn; the returned runner must pin it
    # (matching the seed contract — callers pass the jit expression inline)
    def run(x, rng, lo, steps, fwd, _model_fn=model_fn):
        x, rng, steps, fwd, _ = run5(x, rng, lo, steps, fwd, carry0)
        return x, rng, steps, fwd

    return run
