"""Device-resident block decoding: the fused intra-block step driver.

The legacy sampler runs the denoising loop on host — per step it pays a
jitted dispatch, a host RNG split, and a blocking scalar sync
(``bool(device_get(any(active)))``).  On small/medium models that makes the
decode loop dispatch-bound, not FLOP-bound, hiding exactly the efficiency
gains FDM/FDM-A exist to demonstrate (Table 3 / §5.3).

This module fuses a whole block into ONE compiled XLA program: a
``jax.lax.while_loop`` whose carry is ``(x, rng, steps, fwd)`` —

  x      (B, L) int32   — the token canvas (or the live window, cached path)
  rng    PRNG key       — split *inside* the carry, one split per executed
                          step, the same stream the host loop consumes (so
                          fused and host decoding are bit-identical)
  steps  () int32       — device step counter
  fwd    () float32     — device forward-equivalents counter (f32 because
                          the cached path pro-rates by window length)

Termination is "no active masks left in the block" plus a ``block_size·4``
safety cap matching the host loop's guard.  Every strategy step is fully
traceable (FDM-A's host early-out becomes a ``lax.cond`` — see
``fdm_a_step_fused``), so a block executes with ZERO host round-trips; the
host touches the device once per block to hand over the carry, and the
stats counters come back in a single ``device_get`` at the end of decode.

``block_runner`` is memoized on (model_fn, strategy, configs, n) so repeat
decodes — the serving engine, benchmark warmup+measure pairs — reuse one
compilation per strategy × shape; the block offset ``lo`` is a traced
scalar, so all blocks of a sequence share the same executable.

When is the host loop still right?  Set ``DecodeConfig.fused_loop=False``
to step-debug a strategy (prints / pdb inside step functions), to inspect
per-step canvases, or on backends where long while_loop bodies compile
slowly; ``benchmarks/loop_overhead.py`` A/Bs the two drivers.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.strategies import get_strategy


def drive_block(step_fn: Callable, model_fn: Callable, cfg: ModelConfig,
                dcfg: DecodeConfig, n_per_step: int, x: jnp.ndarray,
                rng, in_block: jnp.ndarray, steps, fwd,
                fwd_scale: float = 1.0):
    """Run one block's denoising steps as a single ``lax.while_loop``.

    Traceable building block (call under jit): ``in_block`` is a (L,) bool
    marking the current block's columns of ``x``; ``steps``/``fwd`` are the
    running device counters, returned advanced.  ``fwd_scale`` pro-rates
    forward-equivalents for the cached path (window / full-seq cost ratio).
    """
    mask_id = cfg.mask_token_id
    max_steps = dcfg.block_size * 4           # matches the host-loop guard
    start = steps

    def active_of(canvas):
        return in_block[None, :] & (canvas == mask_id)

    def cond(carry):
        canvas, _, s, _ = carry
        return jnp.any(active_of(canvas)) & (s - start < max_steps)

    def body(carry):
        canvas, key, s, f = carry
        key, step_key = jax.random.split(key)
        new_canvas, df = step_fn(step_key, canvas, active_of(canvas),
                                 model_fn, cfg, dcfg, n_per_step)
        return (new_canvas, key, s + 1,
                f + jnp.asarray(df, jnp.float32) * fwd_scale)

    return jax.lax.while_loop(cond, body, (x, rng, steps, fwd))


@functools.lru_cache(maxsize=256)
def block_runner(model_fn: Callable, strategy: str, cfg: ModelConfig,
                 dcfg: DecodeConfig, n_per_step: int) -> Callable:
    """One-compilation-per-(strategy × shape) jitted block driver.

    Returns ``run(x, rng, lo, steps, fwd) -> (x, rng, steps, fwd)`` where
    ``lo`` (traced int32) is the block's start column — all blocks of a
    decode, and all later decodes with the same model_fn/configs, share the
    executable.  Memoized so the jit cache survives across ``generate``
    calls (the host loop got this for free from the caller-owned jitted
    model_fn; the fused driver owns the outer jit, so it must cache too).
    """
    step_fn = get_strategy(strategy, fused=True)
    bs = dcfg.block_size

    @jax.jit
    def run(x, rng, lo, steps, fwd):
        pos = jnp.arange(x.shape[1])
        in_block = (pos >= lo) & (pos < lo + bs)
        return drive_block(step_fn, model_fn, cfg, dcfg, n_per_step,
                           x, rng, in_block, steps, fwd)

    return run
