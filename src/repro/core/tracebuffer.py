"""On-device step telemetry: the ``TraceBuffer`` and its strategy adapter.

Observability for the decode *order* — the thing the paper is about —
cannot come from host-side logging: the fused drivers run a whole block
(or a whole request) as one compiled dispatch, and a per-step host sync
would undo exactly the overhead the fused loop removed (ANA001).  So the
trace rides the machinery that already crosses every step boundary: the
strategy carry.

``TracingStrategy`` wraps any registered ``Strategy`` and widens its
carry with a fixed-shape buffer, written with ``.at[ptr].set`` inside
``fused_step``/``step`` — pure array math, trace-safe in
``lax.while_loop``/``lax.scan``, zero extra host syncs.  Because it is
*just a strategy*, every driver (host step loop, per-block fused,
whole-request fused, and their KV-cached twins) records the identical
trace with no driver changes at all.  Layout, per decode of ``S`` steps
on a ``(B, L)`` canvas (``cap = gen_length·4``, the drivers' global
step bound — each block is capped at ``block_size·4`` steps):

* positional half (column-aligned, windowed on the cached path):
  ``commit_step (B, L) i32`` — the step index at which each position's
  surviving token committed (-1 = prompt / never committed; a revoked
  position re-records at its final commit), and ``commit_conf (B, L)
  f32`` — the strategy's confidence for that commit (NaN = the strategy
  offers no attribution).
* global half: per-step ``commits``/``revocations (cap,) i32``,
  ``skipped (cap,) bool`` (the step committed without a forward),
  ``phase (cap,) i32`` (FDM-A's regime, -1 = n/a), ``block (cap,) i32``,
  plus the write pointer ``ptr`` (= steps recorded — it doubles as the
  step index, since steps don't receive a global counter) and the
  current block index ``blk`` (incremented by ``begin_block``).

Commit/revocation detection is strategy-agnostic: a canvas diff against
``mask_token_id`` before/after the inner step.  Confidence attribution
is per-strategy: strategies whose first full-canvas forward is
unconditional declare ``trace_confidence_tap = True`` and the adapter
wraps ``model_fn`` to capture that call's logits (the shape guard skips
FDM's K-folded search forward); strategies that forward inside
``lax.cond`` (extrapolate) expose ``trace_confidence(carry, dcfg)``
instead — tapping a cond branch would leak tracers.

``DecodeTrace`` is the host-side read-back: ONE ``device_get`` at the
end of decode, after the canvas is already synced.  Its
``commit_histogram`` derives per-step FINAL commit counts from
``commit_step`` (not the raw per-step ``commits``), so the counts sum
exactly to the generated-token count even under wino_r revocation.

``tracing(strategy)`` is memoized per wrapped strategy: the Decoder's
runner cache keys subkeys on strategy *identity*, so a fresh wrapper
per call would recompile every decode.  trace=off configs never touch
this module — ``Decoder`` only wraps when ``dcfg.trace`` is set.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.confidence import pallas_enabled, score_logits
from repro.core.strategies import Strategy


def trace_capacity(dcfg: DecodeConfig) -> int:
    """Upper bound on steps per decode: every driver caps a block at
    ``block_size·4`` steps and there are ``gen_length/block_size``
    blocks."""
    return dcfg.gen_length * 4


@dataclasses.dataclass(frozen=True)
class DecodeTrace:
    """Host-side (numpy) view of one decode's TraceBuffer.

    Step arrays are trimmed to the recorded step count; ``commit_step``/
    ``commit_conf`` keep full canvas width (prompt columns are -1/NaN).
    """

    commit_step: np.ndarray    # (B, L) i32; -1 = never committed
    commit_conf: np.ndarray    # (B, L) f32; NaN = no attribution
    commits: np.ndarray        # (S,) i32 raw commits per step
    revocations: np.ndarray    # (S,) i32 re-masked per step
    skipped: np.ndarray        # (S,) bool — step ran without a forward
    phase: np.ndarray          # (S,) i32 FDM-A regime; -1 = n/a
    block: np.ndarray          # (S,) i32 semi-AR block of each step

    @property
    def steps(self) -> int:
        return int(self.commits.shape[0])

    def commit_histogram(self) -> np.ndarray:
        """(steps,) FINAL commit count per step: where each *surviving*
        token committed.  A token revoked and re-decoded counts once, at
        its last commit — so the histogram sums exactly to the number of
        committed positions (``tokens_generated`` for a finished
        decode), which the raw per-step ``commits`` does not under
        revocation."""
        if self.steps == 0:
            return np.zeros((0,), np.int64)
        flat = self.commit_step[self.commit_step >= 0]
        return np.bincount(flat, minlength=self.steps)[: self.steps]

    def slice_rows(self, row: int, pad_cols: int = 0) -> "DecodeTrace":
        """One batch row's view (serving: request ``row`` was left-padded
        by ``pad_cols`` mask columns).  Step arrays are batch-grain and
        shared as-is."""
        return dataclasses.replace(
            self,
            commit_step=self.commit_step[row:row + 1, pad_cols:],
            commit_conf=self.commit_conf[row:row + 1, pad_cols:])

    def summary(self) -> Dict[str, float]:
        conf = self.commit_conf[self.commit_step >= 0]
        finite = conf[np.isfinite(conf)]
        return {
            "steps": self.steps,
            "tokens_committed": int((self.commit_step >= 0).sum()),
            "revocations": int(self.revocations.sum()),
            "skipped_forwards": int(self.skipped.sum()),
            "mean_commit_conf": float(finite.mean()) if finite.size
            else float("nan"),
        }


class TracingStrategy(Strategy):
    """A ``Strategy`` that decodes exactly like ``inner`` while recording
    a TraceBuffer in a widened carry (module docstring has the layout):

        ``((inner_pos, (commit_step, commit_conf)),
           (inner_glob, step_arrays))``

    where ``(inner_pos, inner_glob)`` is the inner carry's own
    positional split (``((), carry)`` for non-positional inners).  The
    structure is uniform either way, so it is an ANA101 fixed-point and
    the cached path windows the positional half — inner leaves and
    commit maps together — with the stock ``carry_window`` machinery.
    """

    positional_carry = True

    def __init__(self, inner: Strategy):
        if isinstance(inner, TracingStrategy):
            raise TypeError("refusing to double-wrap a TracingStrategy")
        self.inner = inner
        self.name = f"{inner.name}+trace"
        self.supports_fused = inner.supports_fused
        self.carry_is_observational = inner.carry_is_observational

    # -- carry plumbing ----------------------------------------------------
    def _split(self, inner_carry) -> Tuple:
        if self.inner.positional_carry:
            pos, glob = inner_carry
            return pos, glob
        return (), inner_carry

    def _join(self, pos, glob):
        return (pos, glob) if self.inner.positional_carry else glob

    def inner_carry(self, carry):
        (ipos, _), (iglob, _) = carry
        return self._join(ipos, iglob)

    def forwards_per_step(self, dcfg: DecodeConfig) -> float:
        return self.inner.forwards_per_step(dcfg)

    def init_carry(self, cfg: ModelConfig, dcfg: DecodeConfig):
        raise TypeError(
            "a traced decode carries per-position state; decode through "
            "Decoder (which calls init_carry_shaped), not the deprecated "
            "carry-less entry points")

    def init_carry_shaped(self, cfg: ModelConfig, dcfg: DecodeConfig,
                          batch: int, length: int):
        inner0 = self.inner.init_carry_shaped(cfg, dcfg, batch, length)
        ipos, iglob = self._split(inner0)
        cap = trace_capacity(dcfg)
        pos_t = (jnp.full((batch, length), -1, jnp.int32),
                 jnp.full((batch, length), jnp.nan, jnp.float32))
        glob_t = (jnp.zeros((cap,), jnp.int32),        # commits
                  jnp.zeros((cap,), jnp.int32),        # revocations
                  jnp.zeros((cap,), bool),             # skipped
                  jnp.full((cap,), -1, jnp.int32),     # phase
                  jnp.zeros((cap,), jnp.int32),        # block
                  jnp.zeros((), jnp.int32),            # ptr (steps)
                  jnp.full((), -1, jnp.int32))         # blk
        return (ipos, pos_t), (iglob, glob_t)

    def begin_block(self, carry, x, in_block):
        (ipos, pos_t), (iglob, glob_t) = carry
        inner_c = self.inner.begin_block(self._join(ipos, iglob),
                                         x, in_block)
        ipos, iglob = self._split(inner_c)
        glob_t = glob_t[:-1] + (glob_t[-1] + 1,)       # blk += 1
        return (ipos, pos_t), (iglob, glob_t)

    def phase_counts(self, carry) -> Dict[str, int]:
        return self.inner.phase_counts(self.inner_carry(carry))

    def carry_stats(self, carry) -> Dict[str, float]:
        return self.inner.carry_stats(self.inner_carry(carry))

    # -- the traced step ---------------------------------------------------
    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        return self._run(self.inner.step, rng, carry, x, active,
                         model_fn, cfg, dcfg, n)

    def fused_step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        return self._run(self.inner.fused_step, rng, carry, x, active,
                         model_fn, cfg, dcfg, n)

    def _run(self, step_fn, rng, carry, x, active, model_fn, cfg, dcfg, n):
        (ipos, (cstep, cconf)), (iglob, glob_t) = carry
        commits, revs, skips, phases, blocks, ptr, blk = glob_t
        inner_c = self._join(ipos, iglob)

        taps = []
        mf = model_fn
        if self.inner.trace_confidence_tap:
            def mf(t, _inner=model_fn):
                logits = _inner(t)
                # first FULL-CANVAS call only: the shape guard skips
                # K-folded search forwards (FDM calls with (K·B, L))
                if not taps and logits.shape[:2] == x.shape:
                    taps.append(logits)
                return logits

        new_x, new_inner, df = step_fn(rng, inner_c, x, active, mf,
                                       cfg, dcfg, n)

        mask = cfg.mask_token_id
        commit = (x == mask) & (new_x != mask)
        revoke = (x != mask) & (new_x == mask)
        if taps:
            conf = score_logits(taps[0], pallas_enabled(dcfg)) \
                .max_prob.astype(jnp.float32)
        else:
            conf = self.inner.trace_confidence(new_inner, dcfg)
            if conf is not None:
                conf = jnp.asarray(conf, jnp.float32)
        nan = jnp.float32(jnp.nan)
        conf_map = conf if conf is not None \
            else jnp.full(x.shape, nan, jnp.float32)
        cstep = jnp.where(commit, ptr, jnp.where(revoke, -1, cstep))
        cconf = jnp.where(commit, conf_map, jnp.where(revoke, nan, cconf))

        ph = self.inner.trace_phase(inner_c, new_inner)
        ph = jnp.asarray(-1 if ph is None else ph, jnp.int32)
        # fixed-shape scatter at the write pointer; 'drop' makes an
        # out-of-capacity step (impossible under the drivers' step caps)
        # a silent no-op instead of undefined indexing
        commits = commits.at[ptr].set(
            jnp.sum(commit, dtype=jnp.int32), mode="drop")
        revs = revs.at[ptr].set(
            jnp.sum(revoke, dtype=jnp.int32), mode="drop")
        skips = skips.at[ptr].set(
            jnp.asarray(df, jnp.float32) == 0, mode="drop")
        phases = phases.at[ptr].set(ph, mode="drop")
        blocks = blocks.at[ptr].set(blk, mode="drop")

        ipos, iglob = self._split(new_inner)
        glob_t = (commits, revs, skips, phases, blocks, ptr + 1, blk)
        return new_x, ((ipos, (cstep, cconf)), (iglob, glob_t)), df

    # -- host read-back ----------------------------------------------------
    def extract(self, carry) -> DecodeTrace:
        """ONE device_get over the final carry's trace leaves."""
        (_, (cstep, cconf)), (_, glob_t) = carry
        commits, revs, skips, phases, blocks, ptr, _ = glob_t
        host = jax.device_get(
            (cstep, cconf, commits, revs, skips, phases, blocks, ptr))
        cstep, cconf, commits, revs, skips, phases, blocks, ptr = host
        s = int(ptr)
        return DecodeTrace(
            commit_step=np.asarray(cstep), commit_conf=np.asarray(cconf),
            commits=np.asarray(commits[:s]),
            revocations=np.asarray(revs[:s]),
            skipped=np.asarray(skips[:s]), phase=np.asarray(phases[:s]),
            block=np.asarray(blocks[:s]))


_TRACING: Dict[int, TracingStrategy] = {}


def tracing(strategy: Strategy) -> TracingStrategy:
    """Memoized wrapper: one ``TracingStrategy`` per inner strategy, ever
    — the runner cache keys on strategy identity, so a fresh wrapper per
    decode would recompile per decode.  The wrapper holds ``inner``
    strongly, keeping the keying ``id`` stable."""
    if isinstance(strategy, TracingStrategy):
        return strategy
    wrapped = _TRACING.get(id(strategy))
    if wrapped is None or wrapped.inner is not strategy:
        wrapped = _TRACING[id(strategy)] = TracingStrategy(strategy)
    return wrapped
