"""Confidence scoring — the quantities every decoding strategy consumes.

Local confidence (Eq. 11): per masked position, the model's certainty about
its own argmax prediction, under three interchangeable metrics (the
heuristic baselines) — max probability, top-2 margin, negative entropy.

Global confidence (Eq. 10): the *foreseeing* term.  For a hypothetical next
state x_t, C_global = E_{p_θ} log p_θ(q, x_t) = -Σ_{j still masked} H_j —
the negative total predictive entropy of the state after the commitment.
Computing it requires ONE forward pass per candidate; FDM batches the K
candidates into the batch axis (one (K·B) forward instead of K sequential
queries — the TPU-native adaptation).

The hot reduction (B, L, V) -> four per-position scalars is served by the
fused Pallas kernel in ``repro.kernels`` when enabled; this module is the
pure-jnp reference semantics.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Scores(NamedTuple):
    """Per-position decode scores, each (B, L) float32."""
    argmax: jnp.ndarray      # int32 — candidate token per position
    max_prob: jnp.ndarray    # p(argmax)
    margin: jnp.ndarray      # p(top1) - p(top2)
    neg_entropy: jnp.ndarray  # Σ_v p log p  (≤ 0)


def pallas_enabled(dcfg=None) -> bool:
    """Resolve a DecodeConfig's ``use_pallas_kernel`` flag.

    ``None`` (the default) means auto: the fused kernel runs only on a real
    TPU backend — on CPU it would execute in Pallas interpret mode, whose
    Python-level emulation costs far more than the jnp reference it
    replaces.  ``True``/``False`` force the choice (tests use ``True`` to
    exercise the wiring through interpret mode).
    """
    flag = getattr(dcfg, "use_pallas_kernel", None) if dcfg is not None \
        else None
    if flag is None:
        return jax.default_backend() == "tpu"
    return bool(flag)


def score_logits(logits: jnp.ndarray,
                 use_kernel: bool = None) -> Scores:
    """One pass over the vocab axis -> all four per-position scores.

    ``use_kernel=True`` routes through the fused single-HBM-pass Pallas
    kernel (``repro.kernels.confidence.confidence_fused``); ``None`` keeps
    the pure-jnp reference (decode callers resolve their config flag via
    ``pallas_enabled`` and pass the result explicitly).
    """
    if use_kernel:
        from repro.kernels.confidence import confidence_fused
        a, p, m, e = confidence_fused(
            logits, interpret=jax.default_backend() != "tpu")
        return Scores(argmax=a, max_prob=p, margin=m, neg_entropy=e)
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    p = jnp.exp(logp)
    top2_p, top2_i = jax.lax.top_k(p, 2)
    neg_ent = jnp.sum(p * logp, axis=-1)
    return Scores(argmax=top2_i[..., 0].astype(jnp.int32),
                  max_prob=top2_p[..., 0],
                  margin=top2_p[..., 0] - top2_p[..., 1],
                  neg_entropy=neg_ent)


def score_logits_sharded(logits: jnp.ndarray) -> Scores:
    """score_logits variant built ONLY from axis reductions (max / argmax /
    masked re-max / sums) — every one partitions cleanly when the vocab
    axis is sharded (GSPMD turns them into per-shard reductions + a scalar
    combine), unlike ``top_k`` which forces a full-vocab all-gather
    (measured: 37 GiB of f32 logits gathered per prefill step, §Perf C2).
    """
    lf = logits.astype(jnp.float32)
    m1 = jnp.max(lf, axis=-1)
    a1 = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    # second max: mask out every occurrence of the max (ties -> margin 0)
    masked = jnp.where(lf >= m1[..., None], -jnp.inf, lf)
    m2 = jnp.max(masked, axis=-1)
    dup = jnp.sum((lf >= m1[..., None]).astype(jnp.int32), axis=-1) > 1
    m2 = jnp.where(dup, m1, m2)
    # stable softmax pieces
    s = jnp.sum(jnp.exp(lf - m1[..., None]), axis=-1)
    u = jnp.sum(lf * jnp.exp(lf - m1[..., None]), axis=-1)
    inv_s = 1.0 / s
    logz = m1 + jnp.log(s)
    max_prob = inv_s
    p2 = jnp.exp(m2 - m1) * inv_s
    neg_ent = u * inv_s - logz
    return Scores(argmax=a1, max_prob=max_prob,
                  margin=max_prob - p2, neg_entropy=neg_ent)


def local_confidence(scores: Scores, metric: str) -> jnp.ndarray:
    """The heuristic ranking score (higher = more confident), (B, L)."""
    if metric == "probability":
        return scores.max_prob
    if metric == "margin":
        return scores.margin
    if metric == "entropy":
        return scores.neg_entropy
    raise ValueError(f"unknown local-confidence metric {metric!r}")


def global_confidence(logits: jnp.ndarray, still_masked: jnp.ndarray
                      ) -> jnp.ndarray:
    """Eq. 10 over a *hypothetical next state*'s logits.

    logits (B, L, V) from the forward pass on the candidate-committed
    sequence; still_masked (B, L) marks positions masked in that state.
    Returns (B,) — Σ_j 1[masked] · Σ_v p log p  (negative total entropy).
    """
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    neg_ent = jnp.sum(jnp.exp(logp) * logp, axis=-1)          # (B, L)
    return jnp.sum(neg_ent * still_masked.astype(jnp.float32), axis=-1)
