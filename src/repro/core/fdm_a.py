"""FDM-A — Acceleration with the Foreseeing Decoding Method (Algorithm 2).

Three phases per step, decided per example from the max-probability profile
of the masked positions (η₁ > η₂ thresholds):

  * **exploration** — no position exceeds η₁: context is scarce, decode a
    single token with the full FDM search (K=K₁, γ=γ₁, n=1);
  * **acceleration** — ≥ N qualified positions (> η₁): context is ample,
    commit min(NUM, N) tokens local-only (FDM with K=1 ⇔ Eq. 18);
  * **balance** — qualified and borderline (η₂ < p ≤ η₁) coexist: commit
    NUM(>η₁) tokens with the foreseeing search over γ=η₂ survivors
    (Eq. 17); if no borderline tokens exist, local-only commit of the
    qualified set (Eq. between 17/18).

Batch handling: each example picks its phase independently (vectorized);
the K-candidate foreseeing forward runs once for the whole batch whenever
*any* example is in a search phase, and each example selects between the
search result and the local-only result.  The search forward is skipped
entirely when every example is in the acceleration phase — this is where
the paper's >3× TPS comes from.  Two implementations of that skip:

  * ``FDMAStrategy.step`` — host early-out (``bool(device_get(...))``), one
    scalar sync per step; used by the legacy host step loop.
  * ``FDMAStrategy.fused_step`` — a ``lax.cond`` over the batched phase
    plan; fully traceable, so the device-resident drivers
    (``core/loop.py``) can run it inside ``lax.while_loop`` with zero host
    syncs while XLA still executes only the taken branch at runtime.

Both variants accumulate the per-step phase histogram into the strategy
carry (a ``(4,)`` int32; see ``FDMAStrategy``), which is how
``SampleStats.phase_counts`` gets populated without extra device syncs.
``fdm_a_step`` / ``fdm_a_step_fused`` survive as carry-less wrappers for
the legacy step-function signature.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.confidence import pallas_enabled, score_logits
from repro.core.fdm import fdm_select
from repro.core.strategies import (ModelFn, Strategy, commit_topn,
                                   register_strategy)


def fdm_a_plan(logits: jnp.ndarray, active: jnp.ndarray,
               dcfg: DecodeConfig):
    """Vectorized phase decision. Returns (n, gamma, need_search) per ex."""
    s = score_logits(logits, pallas_enabled(dcfg))
    p = jnp.where(active, s.max_prob, 0.0)
    qualified = p > dcfg.eta1
    borderline = (p > dcfg.eta2) & ~qualified
    q_cnt = jnp.sum(qualified, axis=-1)                        # (B,)
    b_cnt = jnp.sum(borderline, axis=-1)
    explore = q_cnt == 0
    accel = q_cnt >= dcfg.n_max
    local_only = (~explore) & (~accel) & (b_cnt == 0)
    balance = (~explore) & (~accel) & (b_cnt > 0)
    n = jnp.where(explore, 1, jnp.minimum(q_cnt, dcfg.n_max)).astype(jnp.int32)
    gamma = jnp.where(explore, dcfg.gamma1, dcfg.eta2).astype(jnp.float32)
    need_search = explore | balance
    return s, n, gamma, need_search, (explore, accel, local_only, balance)


PHASES = ("explore", "accel", "local_only", "balance")


def _phase_flags(phases) -> jnp.ndarray:
    """(4,) int32 per-step phase histogram: how many batch examples landed
    in each of Algorithm 2's phases this step (each example is in exactly
    one, so the flags sum to B)."""
    return jnp.stack([jnp.sum(p, dtype=jnp.int32) for p in phases])


def fdm_a_step(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
               dcfg: DecodeConfig, n_unused) -> Tuple[jnp.ndarray, int]:
    """Legacy carry-less entry point (host early-out variant)."""
    new_x, _, fwd = FDM_A.step(rng, jnp.zeros((4,), jnp.int32), x, active,
                               model_fn, cfg, dcfg, n_unused)
    return new_x, fwd


def fdm_a_step_fused(rng, x, active, model_fn: ModelFn, cfg: ModelConfig,
                     dcfg: DecodeConfig, n_unused
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Legacy carry-less entry point (trace-safe ``lax.cond`` variant)."""
    new_x, _, fwd = FDM_A.fused_step(rng, jnp.zeros((4,), jnp.int32), x,
                                     active, model_fn, cfg, dcfg, n_unused)
    return new_x, fwd


class FDMAStrategy(Strategy):
    """Algorithm 2 as a registered ``Strategy``: the strategy itself
    declares its fused form (the ``lax.cond`` early-out) instead of the
    loop driver special-casing it by name.

    The carry is a ``(4,)`` int32 per-phase step counter — each step adds
    the batch's phase histogram, so it rides the fused block/request
    carries to the end of decode and ``Decoder`` reads it back into
    ``SampleStats.phase_counts`` with zero extra syncs.  With batch 1 the
    counts sum to ``stats.steps`` exactly.
    """

    name = "fdm_a"
    carry_is_observational = True    # the counter never steers decoding
    trace_confidence_tap = True      # the scoring forward is unconditional
                                     # and full-canvas (the cond-guarded
                                     # search forward is K-folded, which
                                     # the tap's shape guard skips)

    def init_carry(self, cfg: ModelConfig, dcfg: DecodeConfig):
        return jnp.zeros((4,), jnp.int32)

    def forwards_per_step(self, dcfg: DecodeConfig) -> float:
        return 1.0 + dcfg.k1       # upper bound; the accel phase uses 1

    def phase_counts(self, carry) -> Dict[str, int]:
        vals = jax.device_get(carry)
        return {k: int(v) for k, v in zip(PHASES, vals)}

    def trace_phase(self, carry_before, carry_after):
        """The step's phase for the trace: each step adds the batch's
        phase histogram to the carry, so the argmax of the increment is
        the batch-dominant phase (exact at batch 1 — every example is in
        one phase)."""
        return jnp.argmax(carry_after - carry_before).astype(jnp.int32)

    def step(self, rng, carry, x, active, model_fn: ModelFn,
             cfg: ModelConfig, dcfg: DecodeConfig, n) -> Tuple:
        logits = model_fn(x)
        s, nn, gamma, need_search, phases = fdm_a_plan(logits, active, dcfg)
        carry = carry + _phase_flags(phases)

        # acceleration/local phases: plain local top-n commit (Eq. 18/K=1)
        x_local = commit_topn(x, s.max_prob, s.argmax, active, nn)

        # host early-out: skip the K-forward entirely if nobody searches
        if not bool(jax.device_get(jnp.any(need_search))):
            return x_local, carry, 1

        x_search, extra = fdm_select(x, logits, active, model_fn, cfg,
                                     k=dcfg.k1, gamma=gamma, n=nn,
                                     use_kernel=pallas_enabled(dcfg))
        new_x = jnp.where(need_search[:, None], x_search, x_local)
        return new_x, carry, 1 + extra

    def fused_step(self, rng, carry, x, active, model_fn: ModelFn,
                   cfg: ModelConfig, dcfg: DecodeConfig, n) -> Tuple:
        """Traceable FDM-A step: the acceleration-phase skip is a
        ``lax.cond`` on the batched phase plan instead of a host sync, so
        the whole step lives inside the device-resident loops.  Returns
        the forward count as a traced f32 scalar (1 when the search branch
        is skipped, 1 + K₁ when it runs) for the carry's stats counters.
        """
        logits = model_fn(x)
        s, nn, gamma, need_search, phases = fdm_a_plan(logits, active, dcfg)
        carry = carry + _phase_flags(phases)
        x_local = commit_topn(x, s.max_prob, s.argmax, active, nn)

        def with_search(_):
            x_search, extra = fdm_select(x, logits, active, model_fn, cfg,
                                         k=dcfg.k1, gamma=gamma, n=nn,
                                         use_kernel=pallas_enabled(dcfg))
            new_x = jnp.where(need_search[:, None], x_search, x_local)
            return new_x, jnp.float32(1 + extra)

        def local_only(_):
            return x_local, jnp.float32(1)

        new_x, fwd = jax.lax.cond(jnp.any(need_search), with_search,
                                  local_only, operand=None)
        return new_x, carry, fwd


FDM_A = FDMAStrategy()
register_strategy(FDM_A)
