"""Confidence extrapolation / local determinism propagation
(``extrapolate``) — the carry-ful strategy that SKIPS model forwards.

Local Determinism Propagation (Kong et al., 2025) observes that a masked
position whose confidence trajectory is rising steadily has, in
practice, already settled on its argmax: re-scoring it buys nothing.
This strategy hosts that observation on the ``Strategy.init_carry``
protocol — the carry tracks, per canvas position,

* ``ema``   (B, L) f32 — exponential moving average of the position's
  max-probability confidence (decay ``dcfg.extrap_beta``);
* ``slope`` (B, L) f32 — the EMA's last increment (its discrete slope);
* ``cand``  (B, L) i32 — the argmax candidate from the last real
  forward (what an early commit writes);
* ``nobs``  (B, L) i32 — observation count (extrapolating off a single
  sample is noise, not a trajectory: ``dcfg.extrap_min_obs`` gates it);

plus a global observational ``skipped`` () f32 counter, surfaced as
``SampleStats.skipped_forwards``.

Per step, a position is *ready* when it has enough history and its
extrapolated confidence ``ema + extrap_horizon · slope`` crosses
``extrap_tau`` on a non-falling slope.  When every example in the batch
can fill its commit width from ready positions (or is already done), the
step commits the carried candidates straight from the carry and the
model forward is SKIPPED outright: a ``lax.cond`` in the fused form (XLA
executes no forward at runtime), a host ``device_get`` early-out in the
host form — the decode's forward count genuinely drops.  Otherwise the
step is EXACTLY vanilla confidence ("probability") decoding — one
forward, commit the top-n by max-prob — plus the carry update, which is
what makes the forward-reduction ablation a controlled comparison.

The skip is necessarily batch-global — one batched forward serves every
row, so a single not-ready row forces it — which makes small decode
batches (serving latency, batch 1) the regime where the savings live;
``benchmarks/ablation_carry.py`` measures exactly that regime.

Accounting invariant (plain path): every step either pays 1 forward or
skips 1, so ``steps == forward_equivalents + skipped_forwards``
(parity-tested).  On the cached path forwards are window-pro-rated while
``skipped_forwards`` stays a raw count of avoided model calls.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.confidence import pallas_enabled, score_logits
from repro.core.strategies import (NEG, ModelFn, Strategy, commit_topn,
                                   register_strategy)


class ExtrapolationStrategy(Strategy):
    """Confidence-trajectory extrapolation with forward skipping."""

    name = "extrapolate"
    positional_carry = True

    def init_carry(self, cfg: ModelConfig, dcfg: DecodeConfig):
        raise TypeError(
            "strategy 'extrapolate' carries per-decode positional state; "
            "it needs the canvas shape — decode through Decoder (which "
            "calls init_carry_shaped), not the deprecated carry-less "
            "entry points")

    def init_carry_shaped(self, cfg: ModelConfig, dcfg: DecodeConfig,
                          batch: int, length: int):
        shape = (batch, length)
        pos = (jnp.zeros(shape, jnp.float32),          # ema
               jnp.zeros(shape, jnp.float32),          # slope
               jnp.zeros(shape, jnp.int32),            # cand
               jnp.zeros(shape, jnp.int32))            # nobs
        return pos, (jnp.zeros((), jnp.float32),)      # skipped

    def carry_stats(self, carry) -> Dict[str, float]:
        _, (skipped,) = carry
        return {"skipped_forwards": float(jax.device_get(skipped))}

    def trace_confidence(self, carry, dcfg: DecodeConfig):
        """Commit confidence for the trace: the extrapolated trajectory
        ``ema + horizon·slope`` — the value the commit decision actually
        used.  Read from the post-step carry; a model_fn tap is unsafe
        here (the forward sits inside ``fused_step``'s lax.cond)."""
        (ema, slope, _, _), _ = carry
        return ema + dcfg.extrap_horizon * slope

    # -- the two step halves, shared by the host and fused variants ------
    def _plan(self, carry, x, active, dcfg: DecodeConfig, n):
        """(ready, n_arr, skip): which positions may commit from the
        carry, and whether EVERY example can fill its width that way."""
        (ema, slope, _, nobs), _ = carry
        pred = ema + dcfg.extrap_horizon * slope
        ready = active & (pred >= dcfg.extrap_tau) & (slope >= 0.0) \
            & (nobs >= dcfg.extrap_min_obs)
        n_arr = jnp.broadcast_to(jnp.asarray(n, jnp.int32), (x.shape[0],))
        need = jnp.minimum(n_arr, jnp.sum(active, axis=-1,
                                          dtype=jnp.int32))
        skip = jnp.all(jnp.sum(ready, axis=-1, dtype=jnp.int32) >= need)
        return ready, pred, n_arr, skip

    def _skip_commit(self, carry, x, ready, pred, n_arr):
        """Commit the carried candidates of the top-n ready positions —
        no model call.  The trajectory state is left as-is: remaining
        ready positions keep committing from the carry on later steps
        until a step needs a real forward again."""
        (ema, slope, cand, nobs), (skipped,) = carry
        new_x = commit_topn(x, pred, cand, ready, n_arr)
        return new_x, ((ema, slope, cand, nobs), (skipped + 1.0,)), 0

    def step(self, rng, carry, x, active, model_fn: ModelFn,
             cfg: ModelConfig, dcfg: DecodeConfig, n) -> Tuple:
        ready, pred, n_arr, skip = self._plan(carry, x, active, dcfg, n)
        if bool(jax.device_get(skip)):         # host early-out
            return self._skip_commit(carry, x, ready, pred, n_arr)
        return self._forward(carry, x, active, model_fn, cfg, dcfg, n_arr)

    def fused_step(self, rng, carry, x, active, model_fn: ModelFn,
                   cfg: ModelConfig, dcfg: DecodeConfig, n) -> Tuple:
        """Trace-safe form: the skip is a ``lax.cond``, so the compiled
        program contains both branches but executes only the taken one —
        a skipped step runs no forward on device either."""
        ready, pred, n_arr, skip = self._plan(carry, x, active, dcfg, n)

        def do_skip(_):
            new_x, new_c, _ = self._skip_commit(carry, x, ready, pred,
                                                n_arr)
            return new_x, new_c, jnp.float32(0)

        def do_forward(_):
            new_x, new_c, fwd = self._forward(carry, x, active, model_fn,
                                              cfg, dcfg, n_arr)
            return new_x, new_c, jnp.float32(fwd)

        return jax.lax.cond(skip, do_skip, do_forward, operand=None)

    def _forward(self, carry, x, active, model_fn, cfg, dcfg, n_arr):
        (ema, slope, cand, nobs), (skipped,) = carry
        logits = model_fn(x)
        s = score_logits(logits, pallas_enabled(dcfg))
        # trajectories update wherever the model scored a *masked*
        # position — the active block and the still-masked future blocks
        # (by the time a later block activates, its positions already
        # carry history); committed positions hold their last state
        masked = x == cfg.mask_token_id
        new_ema = jnp.where(masked,
                            dcfg.extrap_beta * ema
                            + (1.0 - dcfg.extrap_beta) * s.max_prob, ema)
        new_slope = jnp.where(masked, new_ema - ema, slope)
        new_cand = jnp.where(masked, s.argmax, cand)
        new_nobs = jnp.where(masked, nobs + 1, nobs)
        new_x = commit_topn(x, jnp.where(active, s.max_prob, NEG),
                            s.argmax, active, n_arr)
        return new_x, ((new_ema, new_slope, new_cand, new_nobs),
                       (skipped,)), 1


register_strategy(ExtrapolationStrategy())
