"""Sharding rules: param/activation pytrees -> PartitionSpec pytrees.

Tensor parallelism (Megatron-style) on the ``model`` mesh axis + fully
sharded data parallelism (FSDP/ZeRO-3) on the ``data``(+``pod``) axes:

* column-parallel (output dim on ``model``): q/k/v projections, MLP
  gate/up, SSM in-projections;
* row-parallel (input dim on ``model``): output projections, MLP down,
  SSM out-projections — GSPMD inserts the block-boundary all-reduce
  exactly like hand-written Megatron;
* the *other* large dim of every ≥2-D weight is sharded on the data axes
  (FSDP): without it, a 236 B-param AdamW state replicated across 16
  data-parallel replicas needs ~177 GB/chip — two orders over the 16 GB
  v5e HBM.  GSPMD all-gathers weights around their use sites;
* expert-parallel: MoE stacked expert weights shard the expert axis on
  ``model`` when E divides it (DeepSeek 160/16), making the router
  dispatch an all-to-all; otherwise (Mixtral 8 experts on 16) experts are
  tensor-parallel in their ffn dim instead;
* every rule is divisibility-guarded: a dim that doesn't divide its mesh
  axis is replicated instead (odd vocabs like whisper's 51865).

Decode-state rules implement two cache regimes: batch ≥ |data| shards the
cache on batch; ``long_500k`` (batch=1) shards the long sequence axis on
``data`` — context parallelism — and the largest remaining dim on
``model``.
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# symbolic rule entries:
#   "model"  — tensor-parallel dim         "fsdp" — data-axes dim
#   "expert" — expert axis (model if divisible, else fall back to ffn TP)
#   "vocab"  — model if divisible else replicated
# first regex match wins; unmatched leaves are replicated.
_PARAM_RULES: List[Tuple[str, Tuple]] = [
    # --- MoE stacked experts ----------------------------------------------
    (r"moe/w_(gate|up)$",   ("expert", "fsdp", "model")),
    (r"moe/w_down$",        ("expert", "model", "fsdp")),
    (r"moe/router$",        (None, None)),
    (r"moe/shared/(gate|up)$", ("fsdp", "model")),
    (r"moe/shared/down$",   ("model", "fsdp")),
    # --- attention ----------------------------------------------------------
    (r"attn/w(q|k|v)$",     ("fsdp", "model")),
    (r"attn/wq_[ab]$",      ("fsdp", "model")),
    (r"attn/w(kv_a|k_b|v_b)$", ("fsdp", "model")),
    (r"attn/wo$",           ("model", "fsdp")),
    # --- dense MLP ----------------------------------------------------------
    (r"mlp/(gate|up|fc1)$", ("fsdp", "model")),
    (r"mlp/(down|fc2)$",    ("model", "fsdp")),
    # --- xLSTM / mamba mixers -----------------------------------------------
    (r"(mixer|mamba)/w_(up|q|k|v|in|gates)$", ("fsdp", "model")),
    (r"mixer/r_gates$",     ("fsdp", "model")),
    (r"(mixer|mamba)/w_(down|out)$", ("model", "fsdp")),
    (r"(mixer|mamba)/w_(i|f|bcdt)$", ("model", None)),
    (r"(mixer|mamba)/a_log$", ("model", None)),
    (r"(mixer|mamba)/conv_w$", (None, "model")),
    # --- embeddings / head ---------------------------------------------------
    (r"embed/tok$",         ("vocab", "fsdp")),
    (r"embed/head$",        ("fsdp", "vocab")),
    (r"embed/pos$",         (None, "model")),
    (r"projector/w$",       ("fsdp", "model")),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ('pod','data') multi-pod, ('data',) single."""
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _resolve(rule: Tuple, shape: Tuple[int, ...], mesh: Mesh,
             fsdp: bool) -> P:
    """Symbolic rule -> concrete PartitionSpec: right-aligned, divisibility
    guarded, no mesh axis used twice."""
    daxes = data_axes(mesh)
    full = [None] * (len(shape) - len(rule)) + list(rule)
    trailing = shape[len(shape) - len(rule):]
    # expert fallback: if the expert axis can't take `model`, move `model`
    # pressure onto the ffn dims (per-expert tensor parallelism)
    if rule and rule[0] == "expert":
        e = trailing[0]
        if e % mesh.shape["model"] == 0:
            full[-len(rule)] = "model"
            full = [("fsdp" if a == "model" and i != len(full) - len(rule)
                     else a) for i, a in enumerate(full)]
            # drop the duplicate fsdp if the rule already placed one
            seen_fsdp = False
            for i, a in enumerate(full):
                if a == "fsdp":
                    if seen_fsdp:
                        full[i] = None
                    seen_fsdp = True
        else:
            full[-len(rule)] = None
    out: List[Optional[Tuple[str, ...]]] = []
    used = set()
    for dim, ax in zip(shape, full):
        concrete: Optional[Tuple[str, ...]] = None
        if ax == "model" or ax == "vocab":
            concrete = ("model",)
        elif ax == "fsdp":
            concrete = daxes if fsdp else None
        elif isinstance(ax, str):
            concrete = (ax,)
        if concrete is not None:
            size = int(np.prod([mesh.shape[a] for a in concrete]))
            if dim % size != 0 or any(a in used for a in concrete):
                concrete = None
        if concrete is not None:
            used.update(concrete)
            out.append(concrete[0] if len(concrete) == 1 else concrete)
        else:
            out.append(None)
    return P(*out)


def param_pspecs(params: Any, mesh: Mesh, fsdp: bool = True) -> Any:
    """PartitionSpec pytree for a parameter (or optimizer-state) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = np.shape(leaf)
        spec = P()   # replicate by default (norms, biases, scalars)
        for pat, rule in _PARAM_RULES:
            if re.search(pat, ps) and len(shape) >= len(rule):
                spec = _resolve(rule, shape, mesh, fsdp)
                break
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspec(mesh: Mesh, ndim: int = 2) -> P:
    """Input batch (B, L, ...) sharded on the data axes."""
    return P(data_axes(mesh), *([None] * (ndim - 1)))


def seq_pspec(mesh: Mesh, ndim: int = 2) -> P:
    """Context parallelism for batch=1 long-context: shard the seq axis."""
    return P(None, data_axes(mesh), *([None] * (ndim - 2)))


def cache_pspecs(state: Any, mesh: Mesh, batch: int) -> Any:
    """Decode-state pytree (leading stacked-layer axis on every leaf)."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    msize = mesh.shape["model"]
    batch_ok = batch % dsize == 0 and batch >= dsize

    def rule(leaf):
        shape = np.shape(leaf)
        if len(shape) <= 1:
            return P()
        spec: List = [None] * len(shape)
        if batch_ok:
            for d in range(1, len(shape)):
                if shape[d] == batch:
                    spec[d] = daxes
                    break
        else:
            # context parallelism: the longest data-divisible axis
            cands = [d for d in range(1, len(shape))
                     if shape[d] >= 1024 and shape[d] % dsize == 0]
            if cands:
                d = max(cands, key=lambda i: shape[i])
                spec[d] = daxes
        # model axis: prefer TRAILING dims (kv-heads / head-dim / latent) so
        # the one-slot decode write stays shard-local; the sequence axis is
        # the fallback
        cands = [d for d in range(len(shape) - 1, 0, -1)
                 if spec[d] is None and shape[d] % msize == 0
                 and shape[d] >= 2 * msize]
        if cands:
            spec[cands[0]] = "model"
        return P(*spec)

    return jax.tree.map(rule, state)


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
