from repro.parallel.sharding import (batch_pspec, cache_pspecs, data_axes,
                                     param_pspecs, seq_pspec, to_named)

__all__ = ["batch_pspec", "cache_pspecs", "data_axes", "param_pspecs",
           "seq_pspec", "to_named"]
