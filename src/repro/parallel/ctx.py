"""Activation-sharding context: logical axis constraints inside the model.

Annotating only jit inputs lets GSPMD propagate shardings freely, and for
FSDP-style weight sharding it routinely resolves conflicts by *replicating
the batch* (we measured a 32 GiB fully-replicated attention-score buffer on
a 3 B model).  Production JAX LMs (MaxText, etc.) pin the activation layout
with ``with_sharding_constraint`` at a handful of seams; this module is
that mechanism, kept optional so the same model code runs un-meshed on the
host (tests, sampler) where the context is unset and ``constrain`` is a
no-op.

Logical symbols:
  "dp" — the data-parallel axes ("pod","data"/"data"): batch dims
  "sp" — sequence parallelism on the ``model`` axis between blocks
         (Megatron-SP; disabled for decode where L == 1)
  "tp" — tensor parallelism on the ``model`` axis: heads / ffn / vocab dims

Every constraint is divisibility-guarded: a dim that doesn't divide its
axis is left unconstrained rather than failing.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "seq_shard": True, "local_moe": True}


def set_activation_mesh(mesh: Optional[Mesh], seq_shard: bool = True,
                        local_moe: bool = True,
                        seq_attn: bool = False,
                        xgather: bool = False) -> None:
    _STATE["mesh"] = mesh
    _STATE["seq_shard"] = seq_shard
    _STATE["local_moe"] = local_moe
    _STATE["seq_attn"] = seq_attn
    _STATE["xgather"] = xgather


@contextmanager
def activation_mesh(mesh: Mesh, seq_shard: bool = True,
                    local_moe: bool = True, seq_attn: bool = False,
                    xgather: bool = False):
    prev = dict(_STATE)
    set_activation_mesh(mesh, seq_shard, local_moe, seq_attn, xgather)
    try:
        yield
    finally:
        _STATE.update(prev)


def option(name: str):
    return _STATE.get(name)


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def shard_counts() -> Tuple[int, int]:
    """(data-axes product, 1) — the grid the shard-local MoE dispatch
    groups tokens by; (1, 1) off-mesh.

    Grouping by the model axis too was measured WORSE (a 264 GiB xs
    all-gather on mixtral train): the grid dim then uses (data × model)
    while the expert ffn dim wants model, and GSPMD resolves the conflict
    by replicating every group.  Data-only groups leave the model axis
    free for the expert ffn tensor parallelism."""
    mesh = _STATE["mesh"]
    if mesh is None or not _STATE["local_moe"]:
        return 1, 1
    gd = int(np.prod([mesh.shape[a] for a in _data_axes(mesh)]))
    return gd, 1


def constrain(x, spec: Tuple) -> jax.Array:
    """Apply a logical-axis sharding constraint (no-op without a mesh)."""
    mesh = _STATE["mesh"]
    if mesh is None or x.ndim != len(spec):
        return x
    out = []
    for dim, sym in zip(x.shape, spec):
        axes: Optional[Tuple[str, ...]] = None
        if sym == "dp":
            axes = _data_axes(mesh)
        elif sym == "tp":
            axes = ("model",)
        elif sym == "sp":
            axes = ("model",) if _STATE["seq_shard"] else None
        elif sym == "grid":
            # must mirror shard_counts(): MoE dispatch groups = data axes
            axes = _data_axes(mesh)
        if axes:
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % size != 0 or dim == 0:
                axes = None
        out.append(None if not axes else
                   (axes[0] if len(axes) == 1 else axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*out)))
