"""Block assembly: one residual block per layer, built from config flags.

Families covered (all bidirectional — diffusion LMs score every masked
position at once, no causal mask exists anywhere):

* dense / vlm:      norm → attn → norm → MLP
* moe:              norm → attn → norm → MoE (shared + routed)
* ssm (xLSTM):      norm → {mLSTM | sLSTM}             (no separate FFN)
* hybrid (Hymba):   norm → [attn ∥ mamba] fused mean   → norm → MLP
* encdec decoder:   norm → self-attn → norm → cross-attn → norm → MLP

Every block has three entry points:
  ``forward``  — full-sequence train/prefill;
  ``decode``   — one token against per-layer state (KVCache / SSM state);
  ``init``     — parameter pytree.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_lib
from repro.models.attention import (attention_cached, attention_capture,
                                    attention_decode, attention_forward,
                                    attention_window, init_attention,
                                    init_cache)
from repro.models.layers import (Params, apply_mlp, apply_norm, init_mlp,
                                 init_norm)
from repro.models.moe import init_moe, moe_forward
from repro.parallel.ctx import constrain

LayerState = Any  # KVCache | ssm state | (KVCache, MambaState) | None


def _is_moe_layer(cfg: ModelConfig, idx: int) -> bool:
    return cfg.is_moe and idx >= cfg.moe.first_k_dense


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_block(rng, cfg: ModelConfig, idx: int) -> Params:
    ks = jax.random.split(rng, 6)
    if cfg.arch_type == "ssm":
        return {"norm1": init_norm(cfg),
                "mixer": ssm_lib.init_xlstm_layer(ks[0], cfg, idx)}
    p: Params = {"norm1": init_norm(cfg),
                 "attn": init_attention(ks[0], cfg),
                 "norm2": init_norm(cfg)}
    if cfg.arch_type == "hybrid":
        p["mamba"] = ssm_lib.init_mamba(ks[1], cfg)
        # learnable fusion of the two parallel head groups (Hymba mean-fuse
        # with per-path norm; we use per-path RMS scales)
        p["mix_attn"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mix_ssm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if _is_moe_layer(cfg, idx):
        p["moe"] = init_moe(ks[2], cfg)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(ks[2], cfg)
    if cfg.is_encdec:
        p["norm_x"] = init_norm(cfg)
        p["xattn"] = init_attention(ks[3], cfg)
    return p


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def block_forward(p: Params, x, positions, cfg: ModelConfig, idx: int,
                  enc_out: Optional[jnp.ndarray] = None,
                  enc_positions: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,L,d) -> (x', aux_loss)."""
    # the residual stream is sequence-parallel between blocks (Megatron-SP)
    x = constrain(x, ("dp", "sp", None))
    aux = jnp.zeros((), jnp.float32)
    if cfg.arch_type == "ssm":
        h = apply_norm(p["norm1"], x, cfg)
        return x + ssm_lib.xlstm_forward(p["mixer"], h, cfg, idx), aux

    h = apply_norm(p["norm1"], x, cfg)
    attn_out = attention_forward(p["attn"], h, positions, cfg)
    if cfg.arch_type == "hybrid":
        ssm_out = ssm_lib.mamba_forward(p["mamba"], h, cfg)
        mixed = 0.5 * (attn_out * p["mix_attn"].astype(x.dtype)
                       + ssm_out * p["mix_ssm"].astype(x.dtype))
        x = x + mixed
    else:
        x = x + attn_out

    if cfg.is_encdec and enc_out is not None:
        h = apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attention(p["xattn"], h, enc_out, cfg)

    if _is_moe_layer(cfg, idx):
        h = apply_norm(p["norm2"], x, cfg)
        out, aux = moe_forward(p["moe"], h, cfg)
        x = x + out
    elif cfg.d_ff:
        h = apply_norm(p["norm2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    return x, aux


def _cross_attention(p: Params, x, enc_out, cfg: ModelConfig) -> jnp.ndarray:
    """Decoder query attends over encoder output (no RoPE on cross path)."""
    dt = x.dtype
    b, lq, _ = x.shape
    lk = enc_out.shape[1]
    hd, nq, nkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = (x @ p["wq"].astype(dt)).reshape(b, lq, nq, hd)
    k = (enc_out.astype(dt) @ p["wk"].astype(dt)).reshape(b, lk, nkv, hd)
    v = (enc_out.astype(dt) @ p["wv"].astype(dt)).reshape(b, lk, nkv, hd)
    from repro.models.attention import _sdpa
    out = _sdpa(q, k, v, None, hd ** -0.5)
    return out.reshape(b, lq, -1) @ p["wo"].astype(dt)


# --------------------------------------------------------------------------
# decode (single new token, per-layer state)
# --------------------------------------------------------------------------

def init_layer_state(cfg: ModelConfig, idx: int, batch: int, length: int,
                     dtype=jnp.bfloat16, valid_length=None) -> LayerState:
    if cfg.arch_type == "ssm":
        return ssm_lib.init_xlstm_state(cfg, idx, batch)
    kv = init_cache(cfg, batch, length, dtype, valid_length=valid_length)
    if cfg.arch_type == "hybrid":
        return (kv, ssm_lib.init_mamba_state(cfg, batch, dtype))
    return kv


def block_decode(p: Params, x, positions, cfg: ModelConfig, idx: int,
                 state: LayerState,
                 enc_out: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, LayerState]:
    """One token (B,1,d) against this layer's state."""
    if cfg.arch_type == "ssm":
        h = apply_norm(p["norm1"], x, cfg)
        out, st = ssm_lib.xlstm_step(p["mixer"], h, cfg, idx, state)
        return x + out, st

    h = apply_norm(p["norm1"], x, cfg)
    if cfg.arch_type == "hybrid":
        kv, ms = state
        attn_out, kv = attention_decode(p["attn"], h, positions, cfg, kv)
        ssm_out, ms = ssm_lib.mamba_step(p["mamba"], h, cfg, ms)
        x = x + 0.5 * (attn_out * p["mix_attn"].astype(x.dtype)
                       + ssm_out * p["mix_ssm"].astype(x.dtype))
        state = (kv, ms)
    else:
        attn_out, state = attention_decode(p["attn"], h, positions, cfg, state)
        x = x + attn_out

    if cfg.is_encdec and enc_out is not None:
        h = apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attention(p["xattn"], h, enc_out, cfg)

    if _is_moe_layer(cfg, idx):
        h = apply_norm(p["norm2"], x, cfg)
        out, _ = moe_forward(p["moe"], h, cfg, capacity_factor=2.0)
        x = x + out
    elif cfg.d_ff:
        h = apply_norm(p["norm2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    return x, state


# --------------------------------------------------------------------------
# fixed-shape block cache (cache_policy = prefix | dual; attention archs)
# --------------------------------------------------------------------------

def block_capture(p: Params, x, positions, cfg: ModelConfig, idx: int,
                  enc_out: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Any]:
    """Full-sequence forward that also emits this layer's fixed-shape
    K/V cache (prefill / block-boundary refresh).  Attention-backed
    archs only — recurrent state cannot ride a scatter-style cache; the
    Decoder gates ssm/hybrid out before ever reaching here."""
    x = constrain(x, ("dp", "sp", None))
    h = apply_norm(p["norm1"], x, cfg)
    attn_out, kv = attention_capture(p["attn"], h, positions, cfg)
    x = x + attn_out

    if cfg.is_encdec and enc_out is not None:
        h = apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attention(p["xattn"], h, enc_out, cfg)

    if _is_moe_layer(cfg, idx):
        h = apply_norm(p["norm2"], x, cfg)
        out, _ = moe_forward(p["moe"], h, cfg, capacity_factor=2.0)
        x = x + out
    elif cfg.d_ff:
        h = apply_norm(p["norm2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    return x, kv


def block_cached(p: Params, x, positions, cfg: ModelConfig, idx: int,
                 cache, win_start,
                 enc_out: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """A W-row live window (B, W, d) against this layer's full-length
    cache; read-only with respect to the cache (refresh = block_capture)."""
    h = apply_norm(p["norm1"], x, cfg)
    x = x + attention_cached(p["attn"], h, positions, cfg, cache, win_start)

    if cfg.is_encdec and enc_out is not None:
        h = apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attention(p["xattn"], h, enc_out, cfg)

    if _is_moe_layer(cfg, idx):
        h = apply_norm(p["norm2"], x, cfg)
        out, _ = moe_forward(p["moe"], h, cfg, capacity_factor=2.0)
        x = x + out
    elif cfg.d_ff:
        h = apply_norm(p["norm2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    return x


# --------------------------------------------------------------------------
# window decode (W tokens vs frozen prefix — cached semi-AR sampling)
# --------------------------------------------------------------------------

def block_window(p: Params, x, positions, cfg: ModelConfig, idx: int,
                 state: LayerState, enc_out: Optional[jnp.ndarray] = None,
                 extend: Optional[str] = None
                 ) -> Tuple[jnp.ndarray, LayerState]:
    """W tokens (B, W, d) against this layer's frozen prefix state.

    ``extend`` selects which half of the state a commit pass updates:
      None         — pure scoring (within-block denoising steps);
      "kv"         — append the window's k/v to the attention cache
                     (callers pass the LIVE window incl. future masks so
                     the cached k/v carry bidirectional context, then
                     reset the valid length to the committed block);
      "recurrent"  — advance the causal recurrent states (xLSTM/mamba)
                     over the window (callers pass the committed block
                     ONLY — causal mixers never see the future anyway).
    """
    if cfg.arch_type == "ssm":
        h = apply_norm(p["norm1"], x, cfg)
        if extend == "recurrent":
            out, st2 = ssm_lib.xlstm_forward(p["mixer"], h, cfg, idx,
                                             state=state, return_state=True)
            return x + out, st2
        out = ssm_lib.xlstm_forward(p["mixer"], h, cfg, idx, state=state)
        return x + out, state

    h = apply_norm(p["norm1"], x, cfg)
    if cfg.arch_type == "hybrid":
        kv, ms = state
        attn_out, kv = attention_window(p["attn"], h, positions, cfg, kv,
                                        extend=extend == "kv")
        if extend == "recurrent":
            ssm_out, ms = ssm_lib.mamba_forward(p["mamba"], h, cfg,
                                                state=ms, return_state=True)
        else:
            ssm_out = ssm_lib.mamba_forward(p["mamba"], h, cfg, state=ms)
        x = x + 0.5 * (attn_out * p["mix_attn"].astype(x.dtype)
                       + ssm_out * p["mix_ssm"].astype(x.dtype))
        state = (kv, ms)
    else:
        attn_out, state = attention_window(p["attn"], h, positions, cfg,
                                           state, extend=extend == "kv")
        x = x + attn_out

    if cfg.is_encdec and enc_out is not None:
        h = apply_norm(p["norm_x"], x, cfg)
        x = x + _cross_attention(p["xattn"], h, enc_out, cfg)

    if _is_moe_layer(cfg, idx):
        h = apply_norm(p["norm2"], x, cfg)
        out, _ = moe_forward(p["moe"], h, cfg, capacity_factor=2.0)
        x = x + out
    elif cfg.d_ff:
        h = apply_norm(p["norm2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    return x, state
