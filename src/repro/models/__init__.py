from repro.models.model import (decode_step, forward, init_decode_state,
                                init_model, make_positions)

__all__ = ["decode_step", "forward", "init_decode_state", "init_model",
           "make_positions"]
