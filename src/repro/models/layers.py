"""Shared layers: norms, embeddings, RoPE variants, MLPs.

Pure-functional style: ``init_*`` builds a params dict, the matching apply
function consumes it.  Params are stored float32 and cast to the compute dtype
at use sites; all matmuls accumulate in float32 via ``preferred_element_type``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.parallel.ctx import constrain

Params = dict


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(rng, shape, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(rng, -3, 3, shape, jnp.float32))


def matmul(x, w, dtype):
    return jax.lax.dot_general(
        x.astype(dtype), w.astype(dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:            # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


def rms_norm_headwise(x, scale, eps: float = 1e-6):
    """Per-head q/k norm (Qwen3): x (..., head_dim), scale (head_dim,)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary / positional embeddings
# --------------------------------------------------------------------------

def rope_frequencies(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x, cos, sin):
    # x: (..., rot_dim) pairs interleaved as [x0..x_{d/2-1} | x_{d/2}..x_{d-1}]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, cfg: ModelConfig, head_dim: Optional[int] = None):
    """x: (B, L, H, hd); positions: (B, L) int32 or (3, B, L) for mrope.

    Variants: 'standard' rotates the full head_dim, 'half' (ChatGLM 2d RoPE)
    rotates the first half only, 'mrope' (Qwen2-VL) splits the rotary dims
    into (t, h, w) sections each driven by its own position stream,
    'sinusoidal'/'none' are no-ops here (absolute embedding added at embed).
    """
    if cfg.rope in ("none", "sinusoidal"):
        return x
    hd = head_dim or x.shape[-1]
    if cfg.rope == "half":
        rot_dim = hd // 2
    else:
        rot_dim = hd
    if cfg.rope == "mrope":
        secs = cfg.mrope_sections
        assert sum(secs) == rot_dim // 2, (secs, rot_dim)
        pos3 = positions if positions.ndim == 3 else jnp.broadcast_to(
            positions[None], (3,) + positions.shape)
        inv = rope_frequencies(rot_dim, cfg.rope_theta)          # (rot/2,)
        # section s of the frequency axis uses position stream s
        sec_ids = jnp.repeat(jnp.arange(3), jnp.array(secs),
                             total_repeat_length=rot_dim // 2)    # (rot/2,)
        pos_per_freq = jnp.take(pos3, sec_ids, axis=0)            # (rot/2,B,L)
        ang = jnp.einsum("fbl,f->blf", pos_per_freq.astype(jnp.float32), inv)
    else:
        inv = rope_frequencies(rot_dim, cfg.rope_theta)
        ang = positions.astype(jnp.float32)[..., None] * inv      # (B,L,rot/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    return jnp.concatenate([_rotate(xr, cos, sin), xp], axis=-1)


def sinusoidal_embedding(length: int, dim: int) -> jnp.ndarray:
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# --------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# --------------------------------------------------------------------------

def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.act == "silu":
        return {"gate": dense_init(ks[0], (d, ff)),
                "up": dense_init(ks[1], (d, ff)),
                "down": dense_init(ks[2], (ff, d))}
    return {"fc1": dense_init(ks[0], (d, ff)),
            "fc2": dense_init(ks[1], (ff, d))}


def apply_mlp(p: Params, x, cfg: ModelConfig):
    dt = x.dtype
    ff_spec = ("dp", None, "tp") if x.ndim == 3 else (None, "tp")
    if "gate" in p:
        h = jax.nn.silu(matmul(x, p["gate"], dt)) * matmul(x, p["up"], dt)
        return matmul(constrain(h, ff_spec), p["down"], dt)
    h = jax.nn.gelu(matmul(x, p["fc1"], dt))
    return matmul(constrain(h, ff_spec), p["fc2"], dt)


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------

def init_embed(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 3)
    p = {"tok": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size))
    if cfg.rope == "sinusoidal":
        p["pos"] = sinusoidal_embedding(cfg.max_seq_len, cfg.d_model)
    return p


def embed_tokens(p: Params, tokens, cfg: ModelConfig, positions=None):
    x = jnp.take(p["tok"], tokens, axis=0).astype(compute_dtype(cfg))
    if "pos" in p and positions is not None:
        pos1 = positions if positions.ndim == 2 else positions[0]
        x = x + jnp.take(p["pos"], pos1, axis=0).astype(x.dtype)
    return x


def lm_head(p: Params, x, cfg: ModelConfig, vocab_sharded: bool = False):
    """``vocab_sharded=True`` keeps the logits sharded on the vocab axis
    (consumers must use reduction-only scoring, see
    ``core.confidence.score_logits_sharded``); the default sequence-
    parallel layout keeps the training loss's label gather vocab-local."""
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = jax.lax.dot_general(
        x.astype(compute_dtype(cfg)), w.astype(compute_dtype(cfg)),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # logits in f32
    if logits.ndim == 3:
        logits = constrain(logits, ("dp", None, "tp") if vocab_sharded
                           else ("dp", "sp", None))
    return logits
