"""DiffusionLM: the model zoo's single entry point.

A masked-diffusion LM over any assigned architecture: bidirectional forward
that scores **all** positions (masked-token prediction head), plus a cached
single-token ``decode_step`` for the serving shapes.

Compile-time design: layers with identical parameter structure are **stacked
and scanned** (``lax.scan`` over the layer axis) instead of unrolled — an
80-layer qwen2-vl lowers as one scanned block body, which keeps dry-run
compiles tractable and is exactly how production JAX LMs (MaxText) do it.
Heterogeneous stacks (DeepSeek's first-dense-layer, xLSTM's s/m pattern)
are grouped into homogeneous runs, each scanned.

Modality frontends are STUBS per the assignment contract: ``audio_stub``
(whisper) consumes precomputed frame embeddings via the encoder stack;
``vision_stub`` (qwen2-vl) prepends precomputed patch embeddings to the
token stream with M-RoPE position ids.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blocks_lib
from repro.models.layers import (Params, apply_norm, compute_dtype,
                                 embed_tokens, init_embed, init_norm, lm_head)


# --------------------------------------------------------------------------
# layer grouping (homogeneous runs -> stacked scan)
# --------------------------------------------------------------------------

def _layer_groups(cfg: ModelConfig) -> List[List[int]]:
    """Partition layer indices into maximal runs with identical param trees."""
    def sig(idx: int) -> str:
        s = ""
        if cfg.arch_type == "ssm":
            from repro.models.ssm import xlstm_kind
            s += xlstm_kind(cfg, idx)
        s += "M" if (cfg.is_moe and idx >= cfg.moe.first_k_dense) else "D"
        return s

    groups: List[List[int]] = []
    for i in range(cfg.num_layers):
        if groups and sig(groups[-1][-1]) == sig(i):
            groups[-1].append(i)
        else:
            groups.append([i])
    return groups


def _stack(trees: List[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def encoder_config(cfg: ModelConfig) -> ModelConfig:
    """The whisper-style encoder is a dense bidirectional stack."""
    return dataclasses.replace(
        cfg, arch_type="dense", num_layers=cfg.encdec.encoder_layers,
        encdec=None, sliding_window=0, remat=cfg.remat)


def init_model(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, cfg.num_layers + 3)
    params: Params = {"embed": init_embed(ks[0], cfg),
                      "norm_f": init_norm(cfg)}
    groups = _layer_groups(cfg)
    params["blocks"] = [
        _stack([blocks_lib.init_block(ks[1 + i], cfg, i) for i in g])
        for g in groups]
    if cfg.is_encdec:
        ecfg = encoder_config(cfg)
        eks = jax.random.split(ks[-1], ecfg.num_layers + 1)
        params["encoder"] = {
            "blocks": [_stack([blocks_lib.init_block(eks[i], ecfg, i)
                               for i in g]) for g in _layer_groups(ecfg)],
            "norm_f": init_norm(ecfg),
        }
    if cfg.encdec is not None and cfg.encdec.frontend == "vision_stub":
        # projector from stub patch embeddings to d_model (the one trained
        # piece of the vision path; the ViT itself is out of scope per spec)
        params["projector"] = {
            "w": jax.random.normal(ks[-2], (cfg.d_model, cfg.d_model),
                                   jnp.float32) * (cfg.d_model ** -0.5)}
    return params


# --------------------------------------------------------------------------
# positions
# --------------------------------------------------------------------------

def make_positions(cfg: ModelConfig, batch: int, length: int,
                   offset: int = 0, num_patches: int = 0) -> jnp.ndarray:
    """Position ids; (3,B,L) for M-RoPE (t/h/w streams: patches get a 2-d
    grid in h/w and constant t; text advances t only — Qwen2-VL scheme)."""
    pos = offset + jnp.arange(length, dtype=jnp.int32)[None].repeat(batch, 0)
    if cfg.rope != "mrope":
        return pos
    side = max(int(num_patches ** 0.5), 1)
    t = jnp.where(pos < num_patches, 0, pos - num_patches + 1)
    hh = jnp.where(pos < num_patches, (pos % (side * side)) // side, t)
    ww = jnp.where(pos < num_patches, pos % side, t)
    return jnp.stack([t, hh, ww])


# --------------------------------------------------------------------------
# forward (train / prefill): score every position
# --------------------------------------------------------------------------

def _run_stack(block_groups, x, positions, cfg: ModelConfig,
               groups: List[List[int]], enc_out=None):
    """Scan each homogeneous group of stacked layers."""
    aux_total = jnp.zeros((), jnp.float32)
    for g_params, g_idx in zip(block_groups, groups):
        rep_idx = g_idx[0]   # any layer in the group has the same structure

        def body(carry, layer_params):
            h, aux = carry
            h2, a = blocks_lib.block_forward(layer_params, h, positions, cfg,
                                             rep_idx, enc_out=enc_out)
            return (h2, aux + a), None

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        if len(g_idx) == 1:
            (x, aux_total), _ = body((x, aux_total),
                                     jax.tree.map(lambda a: a[0], g_params))
        elif cfg.unroll:
            for i in range(len(g_idx)):
                (x, aux_total), _ = body(
                    (x, aux_total), jax.tree.map(lambda a: a[i], g_params))
        else:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), g_params)
    return x, aux_total


def encode(params: Params, enc_embeds: jnp.ndarray,
           cfg: ModelConfig) -> jnp.ndarray:
    """Run the encoder stack over stub frame embeddings (B, S_enc, d)."""
    ecfg = encoder_config(cfg)
    b, l, _ = enc_embeds.shape
    pos = make_positions(ecfg, b, l)
    x = enc_embeds.astype(compute_dtype(cfg))
    x, _ = _run_stack(params["encoder"]["blocks"], x, pos, ecfg,
                      _layer_groups(ecfg))
    return apply_norm(params["encoder"]["norm_f"], x, ecfg)


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            enc_embeds: Optional[jnp.ndarray] = None,
            patch_embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            return_hidden: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, L) -> (logits (B, L, V) float32, aux_loss scalar).

    Bidirectional: every (masked or committed) position is scored.
    ``return_hidden=True`` skips the LM head and returns the final hidden
    states instead (callers that reduce logits chunk-wise — prefill
    scoring — avoid materializing (B, L, V) in one piece).
    """
    b, l = tokens.shape
    num_patches = 0
    x = embed_tokens(params["embed"], tokens, cfg,
                     positions=jnp.arange(l)[None].repeat(b, 0))
    if patch_embeds is not None:
        proj = patch_embeds.astype(x.dtype) @ \
            params["projector"]["w"].astype(x.dtype)
        x = jnp.concatenate([proj, x], axis=1)
        num_patches = patch_embeds.shape[1]
    if positions is None:
        positions = make_positions(cfg, b, x.shape[1],
                                   num_patches=num_patches)
    enc_out = None
    if cfg.is_encdec and enc_embeds is not None:
        enc_out = encode(params, enc_embeds, cfg)
    x, aux = _run_stack(params["blocks"], x, positions, cfg,
                        _layer_groups(cfg), enc_out=enc_out)
    x = apply_norm(params["norm_f"], x, cfg)
    if num_patches:
        x = x[:, num_patches:]
    if return_hidden:
        return x, aux
    logits = lm_head(params["embed"], x, cfg)
    return logits, aux


# --------------------------------------------------------------------------
# decode (one token against per-layer caches/states)
# --------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Per-group stacked layer states + the scalar position cursor."""
    layer_states: Tuple[Any, ...]
    enc_out: Optional[jnp.ndarray]


def init_decode_state(cfg: ModelConfig, batch: int, length: int,
                      dtype=jnp.bfloat16,
                      enc_out: Optional[jnp.ndarray] = None,
                      valid_length: Optional[int] = None) -> DecodeState:
    groups = _layer_groups(cfg)
    states = []
    for g in groups:
        sts = [blocks_lib.init_layer_state(cfg, i, batch, length, dtype,
                                           valid_length=valid_length)
               for i in g]
        states.append(_stack(sts))   # leading layer axis (len(g), ...)
    return DecodeState(layer_states=tuple(states), enc_out=enc_out)


def decode_step(params: Params, token: jnp.ndarray, position: jnp.ndarray,
                state: DecodeState, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, DecodeState]:
    """token (B, 1) at ``position`` (B, 1) -> (logits (B,1,V), new state)."""
    b = token.shape[0]
    x = embed_tokens(params["embed"], token, cfg, positions=position)
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(position[None], (3, b, 1))
    else:
        positions = position
    groups = _layer_groups(cfg)
    new_states = []
    for g_params, g_states, g_idx in zip(params["blocks"],
                                         state.layer_states, groups):
        rep_idx = g_idx[0]

        def body(h, scan_in):
            layer_params, layer_state = scan_in
            h2, st2 = blocks_lib.block_decode(layer_params, h, positions, cfg,
                                              rep_idx, layer_state,
                                              enc_out=state.enc_out)
            return h2, st2

        if len(g_idx) == 1:
            one = jax.tree.map(lambda a: a[0], (g_params, g_states))
            x, st2 = body(x, one)
            new_states.append(jax.tree.map(lambda a: a[None], st2))
        elif cfg.unroll:
            sts = []
            for i in range(len(g_idx)):
                one = jax.tree.map(lambda a: a[i], (g_params, g_states))
                x, st2 = body(x, one)
                sts.append(st2)
            new_states.append(
                jax.tree.map(lambda *xs: jnp.stack(xs), *sts))
        else:
            x, sts = jax.lax.scan(body, x, (g_params, g_states))
            new_states.append(sts)
    x = apply_norm(params["norm_f"], x, cfg)
    logits = lm_head(params["embed"], x, cfg)
    return logits, DecodeState(layer_states=tuple(new_states),
                               enc_out=state.enc_out)


# --------------------------------------------------------------------------
# fixed-shape block cache (cache_policy = prefix | dual)
# --------------------------------------------------------------------------

def capture_cache(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
                  enc_out: Optional[jnp.ndarray] = None) -> DecodeState:
    """One full bidirectional pass over the canvas (B, total) capturing
    every layer's fixed-shape K/V — the prefill / block-boundary refresh
    op of the block cache (DESIGN.md "The KV cache").  Skips the LM head:
    refresh logits are never consumed (the next windowed forward
    re-scores the live rows anyway).  Unlike ``init_decode_state`` +
    extend, the cache always covers ALL ``total`` positions, so every
    shape stays static and the result can ride a ``lax.scan`` carry."""
    b, l = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg,
                     positions=jnp.arange(l)[None].repeat(b, 0))
    pos = make_positions(cfg, b, l)
    groups = _layer_groups(cfg)
    states = []
    for g_params, g_idx in zip(params["blocks"], groups):
        rep_idx = g_idx[0]

        def body(h, layer_params):
            return blocks_lib.block_capture(layer_params, h, pos, cfg,
                                            rep_idx, enc_out=enc_out)

        if len(g_idx) == 1:
            x, kv = body(x, jax.tree.map(lambda a: a[0], g_params))
            states.append(jax.tree.map(lambda a: a[None], kv))
        elif cfg.unroll:
            kvs = []
            for i in range(len(g_idx)):
                x, kv = body(x, jax.tree.map(lambda a: a[i], g_params))
                kvs.append(kv)
            states.append(jax.tree.map(lambda *xs: jnp.stack(xs), *kvs))
        else:
            x, kvs = jax.lax.scan(body, x, g_params)
            states.append(kvs)
    return DecodeState(layer_states=tuple(states), enc_out=enc_out)


def forward_cached(params: Params, tokens: jnp.ndarray, win_start,
                   state: DecodeState, cfg: ModelConfig) -> jnp.ndarray:
    """Score a W-row live window (B, W) at traced offset ``win_start``
    against the fixed-shape cache from ``capture_cache``.  Read-only with
    respect to the cache: each layer scatters its fresh window K/V into a
    functional copy and attends over all ``total`` keys — cached context
    outside the window, fresh inside.  Returns logits (B, W, V)."""
    b, w = tokens.shape
    epos = win_start + jnp.arange(w, dtype=jnp.int32)[None].repeat(b, 0)
    x = embed_tokens(params["embed"], tokens, cfg, positions=epos)
    if cfg.rope == "mrope":
        # slice the full-canvas position ids so cached and fresh K agree
        total = state.layer_states[0].k.shape[2]
        pos = jax.lax.dynamic_slice_in_dim(
            make_positions(cfg, b, total), win_start, w, axis=-1)
    else:
        pos = epos
    groups = _layer_groups(cfg)
    for g_params, g_states, g_idx in zip(params["blocks"],
                                         state.layer_states, groups):
        rep_idx = g_idx[0]

        def body(h, scan_in):
            layer_params, layer_cache = scan_in
            h2 = blocks_lib.block_cached(layer_params, h, pos, cfg, rep_idx,
                                         layer_cache, win_start,
                                         enc_out=state.enc_out)
            return h2, None

        if len(g_idx) == 1:
            one = jax.tree.map(lambda a: a[0], (g_params, g_states))
            x, _ = body(x, one)
        elif cfg.unroll:
            for i in range(len(g_idx)):
                one = jax.tree.map(lambda a: a[i], (g_params, g_states))
                x, _ = body(x, one)
        else:
            x, _ = jax.lax.scan(body, x, (g_params, g_states))
    x = apply_norm(params["norm_f"], x, cfg)
    return lm_head(params["embed"], x, cfg)


def set_valid_length(state: DecodeState, length) -> DecodeState:
    """Reset the attention caches' valid count (after a live-window "kv"
    extend wrote k/v for future-mask positions beyond the commit)."""
    from repro.models.attention import KVCache

    def fix(st):
        if isinstance(st, KVCache):
            return st._replace(length=jnp.full_like(st.length, length))
        if isinstance(st, tuple) and len(st) == 2 \
                and isinstance(st[0], KVCache):
            return (st[0]._replace(length=jnp.full_like(st[0].length,
                                                        length)), st[1])
        return st

    return DecodeState(
        layer_states=tuple(fix(s) for s in state.layer_states),
        enc_out=state.enc_out)


def forward_window(params: Params, tokens: jnp.ndarray,
                   positions: jnp.ndarray, state: DecodeState,
                   cfg: ModelConfig, extend: Optional[str] = None
                   ) -> Tuple[jnp.ndarray, DecodeState]:
    """Score a W-token window (B, W) against the frozen prefix state —
    the cached semi-AR sampling path (Fast-dLLM-style): within-block
    denoising re-scores only the active block, committed blocks live in
    the per-layer caches/recurrent states.  ``extend=True`` appends the
    window to the prefix (once per committed block)."""
    b, w = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg, positions=positions)
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(positions[None], (3, b, w))
    else:
        pos = positions
    groups = _layer_groups(cfg)
    new_states = []
    for g_params, g_states, g_idx in zip(params["blocks"],
                                         state.layer_states, groups):
        rep_idx = g_idx[0]

        def body(h, scan_in):
            layer_params, layer_state = scan_in
            h2, st2 = blocks_lib.block_window(layer_params, h, pos, cfg,
                                              rep_idx, layer_state,
                                              enc_out=state.enc_out,
                                              extend=extend)
            return h2, st2

        if len(g_idx) == 1:
            one = jax.tree.map(lambda a: a[0], (g_params, g_states))
            x, st2 = body(x, one)
            new_states.append(jax.tree.map(lambda a: a[None], st2))
        else:
            x, sts = jax.lax.scan(body, x, (g_params, g_states))
            new_states.append(sts)
    x = apply_norm(params["norm_f"], x, cfg)
    logits = lm_head(params["embed"], x, cfg)
    return logits, DecodeState(layer_states=tuple(new_states),
                               enc_out=state.enc_out)
