"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and a Mamba-style
selective-scan head (Hymba's SSM half).

TPU adaptation notes
--------------------
* **mLSTM** uses the *chunkwise-parallel* formulation: ``lax.scan`` over
  chunks of 128 tokens carrying the (head, d_k, d_v) matrix state; within a
  chunk the contribution is a dense MXU matmul.  This keeps the training
  forward sub-quadratic (O(L·d²) not O(L²·d)) while the per-chunk work is
  systolic-friendly — the TPU analogue of the paper's GPU kernel fusion.
* **sLSTM** is a strict token recurrence (exponential gating with a
  normalizer/stabilizer state), expressed with ``lax.scan`` over time.
* **Mamba head** (Hymba) uses a diagonal selective SSM evaluated with
  ``lax.associative_scan`` — log-depth on the sequence axis, which is the
  TPU-native replacement for the CUDA selective-scan kernel.
* Every mixer exposes a matching ``*_step`` for O(1)-per-token decode
  carrying recurrent state instead of a KV cache — this is the sub-quadratic
  path that makes ``long_500k`` admissible for ssm/hybrid archs.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init
from repro.parallel.ctx import constrain

CHUNK = 128  # mLSTM chunk length (MXU-aligned)


# ==========================================================================
# mLSTM (matrix-memory LSTM) — xLSTM's parallelizable block
# ==========================================================================

class MLSTMState(NamedTuple):
    """Per-layer recurrent state for decode: C (B,H,dk,dv), n (B,H,dk), m (B,H)."""
    c: jnp.ndarray
    n: jnp.ndarray
    m: jnp.ndarray


def init_mlstm(rng, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    h = s.num_ssm_heads
    ks = jax.random.split(rng, 7)
    return {
        "w_up": dense_init(ks[0], (d, di)),          # pre-projection
        "w_q": dense_init(ks[1], (di, di)),
        "w_k": dense_init(ks[2], (di, di)),
        "w_v": dense_init(ks[3], (di, di)),
        "w_i": dense_init(ks[4], (di, h), scale=0.02),  # input gate (per head)
        "w_f": dense_init(ks[5], (di, h), scale=0.02),  # forget gate
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),     # bias toward remembering
        "w_down": dense_init(ks[6], (di, d)),
        "skip_scale": jnp.ones((di,), jnp.float32),  # learnable skip
    }


def _mlstm_heads(p: Params, x, cfg: ModelConfig):
    """Project x (B,L,d) -> q,k,v (B,L,H,dh) and gate pre-activations (B,L,H)."""
    s = cfg.ssm
    dt = x.dtype
    inner = constrain(x @ p["w_up"].astype(dt), ("dp", None, "tp"))
    b, l, di = inner.shape
    h = s.num_ssm_heads
    dh = di // h
    q = (inner @ p["w_q"].astype(dt)).reshape(b, l, h, dh)
    k = (inner @ p["w_k"].astype(dt)).reshape(b, l, h, dh) * (dh ** -0.5)
    v = (inner @ p["w_v"].astype(dt)).reshape(b, l, h, dh)
    i_pre = (inner @ p["w_i"].astype(dt)).astype(jnp.float32) + p["b_i"]
    f_pre = (inner @ p["w_f"].astype(dt)).astype(jnp.float32) + p["b_f"]
    return inner, q, k, v, i_pre, f_pre


def mlstm_forward(p: Params, x, cfg: ModelConfig,
                  state: "MLSTMState" = None,
                  return_state: bool = False):
    """Chunkwise-parallel mLSTM over the full sequence (training/prefill).

    Exponential gating in log space (stabilizer m) following the xLSTM paper;
    inter-chunk state is a scan, intra-chunk is dense matmuls.
    ``state`` seeds the scan (frozen-prefix cached decoding);
    ``return_state=True`` also returns the end-of-sequence state.
    """
    dt = x.dtype
    inner, q, k, v, i_pre, f_pre = _mlstm_heads(p, x, cfg)
    b, l, h, dh = q.shape
    # pad to a chunk multiple
    pad = (-l) % CHUNK
    if pad:
        def zf(a):
            return jnp.pad(a,
                           ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        # padded steps must be state-IDENTITY (forget ≈ 1, input ≈ 0) so
        # the final carry is exact for cached decoding; padded OUTPUTS are
        # sliced off regardless
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-30.0)
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=30.0)
    nc = q.shape[1] // CHUNK

    def rs(a):
        return a.reshape(b, nc, CHUNK, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = rs(q), rs(k), rs(v)                  # (nc,B,C,H,dh)
    ic, fc = rs(i_pre), rs(f_pre)                     # (nc,B,C,H)

    logf = jax.nn.log_sigmoid(fc)                     # (nc,B,C,H) f32
    csum = jnp.cumsum(logf, axis=2)                   # within-chunk cumulative

    def chunk_step(carry, inp):
        c_state, n_state, m_state = carry             # (B,H,dk,dv),(B,H,dk),(B,H)
        qch, kch, vch, ich, logfch, cch = inp
        # log decay from chunk start to position t (inclusive of t's forget)
        a = cch                                        # (B,C,H)
        total = cch[:, -1]                             # (B,H) full-chunk decay
        # keys' outgoing decay: from t+1..C  => total - a
        log_i = ich                                    # (B,C,H)
        # stabilizer: running max of (m_prev + a, intra scores)
        m_inter = m_state + total                      # (B,H)
        m_intra = jnp.max(log_i + (total[:, None] - a), axis=1)  # (B,H)
        m_new = jnp.maximum(m_inter, m_intra)
        # inter-chunk contribution: q_t decayed from chunk start
        q_scale = jnp.exp(a + m_state[:, None] - m_new[:, None])   # (B,C,H)
        inter = jnp.einsum("bchk,bhkv->bchv", qch.astype(jnp.float32) *
                           q_scale[..., None], c_state)
        n_inter = jnp.einsum("bchk,bhk->bch", qch.astype(jnp.float32) *
                             q_scale[..., None], n_state)
        # intra-chunk: masked quadratic within the 128-token chunk (MXU matmul)
        # decay from j to t: a_t - a_j, valid for j <= t
        dmat = a[:, :, None] - a[:, None, :]           # (B,C,C,H) t,j
        gate = jnp.exp(dmat + log_i[:, None] - m_new[:, None, None])
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))
        gate = jnp.where(tri[None, :, :, None], gate, 0.0)
        scores = jnp.einsum("bthk,bjhk->btjh", qch.astype(jnp.float32),
                            kch.astype(jnp.float32)) * gate
        intra = jnp.einsum("btjh,bjhv->bthv", scores, vch.astype(jnp.float32))
        n_intra = jnp.sum(scores, axis=2)              # (B,C,H)
        # combine + normalize (|n q| max with exp(-m) per xLSTM eq. 26)
        num = inter + intra
        den = jnp.maximum(jnp.abs(n_inter + n_intra),
                          jnp.exp(-m_new)[:, None]) + 1e-6
        out = (num / den[..., None]).astype(dt)
        # state update: C' = exp(total) C + sum_j exp(total - a_j + i_j) k_j v_j^T
        k_scale = jnp.exp((total[:, None] - a) + log_i - m_new[:, None])
        kw = kch.astype(jnp.float32) * k_scale[..., None]
        c_new = (jnp.exp(m_state + total - m_new)[..., None, None] * c_state
                 + jnp.einsum("bchk,bchv->bhkv", kw, vch.astype(jnp.float32)))
        n_new = (jnp.exp(m_state + total - m_new)[..., None] * n_state
                 + jnp.sum(kw, axis=1))
        return (c_new, n_new, m_new), out

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state.c, state.n, state.m
    carry, outs = jax.lax.scan(chunk_step, (c0, n0, m0),
                               (qc, kc, vc, ic, logf, csum))
    out = outs.swapaxes(0, 1).reshape(b, nc * CHUNK, h, dh)[:, :l]
    out = out.reshape(b, l, h * dh)
    out = out + inner * jax.nn.silu(p["skip_scale"].astype(dt))
    out = out @ p["w_down"].astype(dt)
    if return_state:
        # padded steps are gate-identities (see padding above) so the
        # carry is exact at any length
        return out, MLSTMState(*carry)
    return out


def mlstm_step(p: Params, x, cfg: ModelConfig,
               state: MLSTMState) -> Tuple[jnp.ndarray, MLSTMState]:
    """One-token decode (B,1,d) carrying (C,n,m) state — O(d²) per token."""
    dt = x.dtype
    inner, q, k, v, i_pre, f_pre = _mlstm_heads(p, x, cfg)
    b, _, h, dh = q.shape
    q1, k1, v1 = (a[:, 0].astype(jnp.float32) for a in (q, k, v))  # (B,H,dh)
    logf = jax.nn.log_sigmoid(f_pre[:, 0])            # (B,H)
    logi = i_pre[:, 0]
    m_new = jnp.maximum(state.m + logf, logi)
    fdec = jnp.exp(state.m + logf - m_new)
    iw = jnp.exp(logi - m_new)
    c_new = fdec[..., None, None] * state.c + iw[..., None, None] * \
        jnp.einsum("bhk,bhv->bhkv", k1, v1)
    n_new = fdec[..., None] * state.n + iw[..., None] * k1
    num = jnp.einsum("bhk,bhkv->bhv", q1, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q1, n_new)),
                      jnp.exp(-m_new)) + 1e-6
    out = (num / den[..., None]).astype(dt).reshape(b, 1, h * dh)
    out = out + inner * jax.nn.silu(p["skip_scale"].astype(dt))
    return out @ p["w_down"].astype(dt), MLSTMState(c_new, n_new, m_new)


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    s = cfg.ssm
    dh = s.expand * cfg.d_model // s.num_ssm_heads
    return MLSTMState(
        c=jnp.zeros((batch, s.num_ssm_heads, dh, dh), jnp.float32),
        n=jnp.zeros((batch, s.num_ssm_heads, dh), jnp.float32),
        m=jnp.full((batch, s.num_ssm_heads), -1e30, jnp.float32))


# ==========================================================================
# sLSTM (scalar-memory LSTM with exponential gating)
# ==========================================================================

class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, di)
    n: jnp.ndarray   # (B, di)
    m: jnp.ndarray   # (B, di)
    h: jnp.ndarray   # (B, di) hidden fed back into gates


def init_slstm(rng, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    ks = jax.random.split(rng, 4)
    return {
        "w_up": dense_init(ks[0], (d, di)),
        "w_gates": dense_init(ks[1], (di, 4 * di)),      # z,i,f,o from input
        "r_gates": dense_init(ks[2], (di, 4 * di), scale=0.02),  # recurrent
        "b_gates": jnp.concatenate([jnp.zeros((2 * di,), jnp.float32),
                                    jnp.full((di,), 3.0, jnp.float32),
                                    jnp.zeros((di,), jnp.float32)]),
        "w_down": dense_init(ks[3], (di, d)),
    }


def _slstm_cell(p: Params, xt, st: SLSTMState, di: int):
    """xt: (B, di) pre-projected input; one exponential-gated LSTM step."""
    pre = (xt.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32)
           + st.h @ p["r_gates"].astype(jnp.float32) + p["b_gates"])
    z, i_pre, f_pre, o = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + st.m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + st.m - m_new)
    c_new = f_g * st.c + i_g * jnp.tanh(z)
    n_new = f_g * st.n + i_g
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c_new, n_new, m_new, h_new)


def slstm_forward(p: Params, x, cfg: ModelConfig,
                  state: "SLSTMState" = None, return_state: bool = False):
    """Sequential scan over time (the sLSTM is inherently recurrent)."""
    s = cfg.ssm
    dt = x.dtype
    di = s.expand * cfg.d_model
    inner = (x @ p["w_up"].astype(dt)).astype(jnp.float32)   # (B,L,di)
    b = x.shape[0]
    st0 = state if state is not None else init_slstm_state(cfg, b)

    def step(st, xt):
        st2 = _slstm_cell(p, xt, st, di)
        return st2, st2.h

    st_end, hs = jax.lax.scan(step, st0, inner.swapaxes(0, 1))
    out = hs.swapaxes(0, 1).astype(dt)                       # (B,L,di)
    out = out @ p["w_down"].astype(dt)
    if return_state:
        return out, st_end
    return out


def slstm_step(p: Params, x, cfg: ModelConfig,
               state: SLSTMState) -> Tuple[jnp.ndarray, SLSTMState]:
    dt = x.dtype
    di = cfg.ssm.expand * cfg.d_model
    inner = (x @ p["w_up"].astype(dt)).astype(jnp.float32)[:, 0]
    st2 = _slstm_cell(p, inner, state, di)
    return (st2.h.astype(dt)[:, None] @ p["w_down"].astype(dt)), st2


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    di = cfg.ssm.expand * cfg.d_model
    z = jnp.zeros((batch, di), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full((batch, di), -1e30, jnp.float32),
                      h=z)


# ==========================================================================
# Mamba-style selective SSM head (Hymba's parallel SSM path)
# ==========================================================================

class MambaState(NamedTuple):
    """h: (B, di, N) diagonal SSM state; conv: (B, K-1, di) rolling buffer."""
    h: jnp.ndarray
    conv: jnp.ndarray


def init_mamba(rng, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    n = s.state_size
    ks = jax.random.split(rng, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di)),          # x and gate z
        "conv_w": dense_init(ks[1], (s.conv_kernel, di), scale=0.5),
        "w_bcdt": dense_init(ks[2], (di, 2 * n + 1), scale=0.02),  # B, C, dt
        "a_log": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                         )[None, :].repeat(di, 0),        # (di, N) neg-real A
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),    # softplus ≈ 0.01
        "w_out": dense_init(ks[3], (di, d)),
    }


def _mamba_inputs(p: Params, x, cfg: ModelConfig):
    dt_ = x.dtype
    xz = x @ p["w_in"].astype(dt_)
    xin, z = jnp.split(xz, 2, axis=-1)                   # (B,L,di) each
    return (constrain(xin, ("dp", None, "tp")),
            constrain(z, ("dp", None, "tp")))


def _mamba_conv_full(p: Params, xin, cfg: ModelConfig):
    """Depthwise causal conv along L (width K). xin (B,L,di)."""
    k = cfg.ssm.conv_kernel
    pad = jnp.pad(xin, ((0, 0), (k - 1, 0), (0, 0)))
    w = p["conv_w"].astype(xin.dtype)                    # (K, di)
    out = sum(pad[:, i:i + xin.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out)


def _mamba_scan_terms(p: Params, xc, cfg: ModelConfig):
    """Selective params: decay a_t=(B,L,di,N), input b_t x_t, readout C."""
    n = cfg.ssm.state_size
    bcdt = (xc @ p["w_bcdt"].astype(xc.dtype)).astype(jnp.float32)
    b_sel, c_sel, dt_pre = jnp.split(bcdt, [n, 2 * n], axis=-1)
    # low-rank dt (scalar per position, broadcast over channels + per-channel
    # bias) — the rank-1 form of mamba's dt projection
    delta = jax.nn.softplus(dt_pre + p["dt_bias"])           # (B,L,di)
    a = -jnp.exp(p["a_log"])                              # (di,N)
    decay = jnp.exp(delta[..., None] * a)                 # (B,L,di,N)
    drive = (delta[..., None] * b_sel[:, :, None, :]
             * xc.astype(jnp.float32)[..., None])         # (B,L,di,N)
    return decay, drive, c_sel


MAMBA_CHUNK = 256   # selective-scan chunk (memory: B·CHUNK·di·N live)


def mamba_forward(p: Params, x, cfg: ModelConfig,
                  state: "MambaState" = None, return_state: bool = False):
    """Chunked selective scan: lax.scan over CHUNK-sized pieces carrying
    the (B, di, N) state, associative_scan (log-depth) within a chunk.

    The naive full-length associative_scan materializes log₂(L) copies of
    the (B, L, di, N) state tensor — measured 28 s of HBM traffic and an
    87 GiB/dev peak on hymba × train_4k (§Perf iteration D1); chunking
    caps the live working set at (B, CHUNK, di, N) and was confirmed to
    move the bottleneck off memory.
    """
    xin, z = _mamba_inputs(p, x, cfg)
    if state is not None:
        # frozen-prefix decoding: the conv left-pad is the prefix tail
        k = cfg.ssm.conv_kernel
        xin_pad = jnp.concatenate([state.conv.astype(xin.dtype), xin], 1)
        w = p["conv_w"].astype(xin.dtype)
        xc = jax.nn.silu(sum(xin_pad[:, i:i + xin.shape[1]] * w[i]
                             for i in range(k)))
    else:
        xc = _mamba_conv_full(p, xin, cfg)                # (B,L,di)
    b, l, di = xc.shape
    n = cfg.ssm.state_size
    pad = (-l) % MAMBA_CHUNK
    if pad:
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p = xc
    nch = xc_p.shape[1] // MAMBA_CHUNK
    xcc = xc_p.reshape(b, nch, MAMBA_CHUNK, di).swapaxes(0, 1)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_body(h0, xc_chunk):                         # h0 (B,di,N)
        decay, drive, c_sel = _mamba_scan_terms(p, xc_chunk, cfg)
        # (§Perf D2, refuted on this harness): casting the (B,C,di,N)
        # selective-state tensors to bf16 measured *equal* bytes because
        # the CPU backend re-legalizes bf16 elementwise ops to f32; on a
        # TPU it would halve traffic.  Kept f32 for numerical simplicity —
        # the real fix is a fused Pallas selective-scan kernel (the TPU
        # analogue of CUDA mamba's kernel), recorded as future work.
        a_cum, h = jax.lax.associative_scan(combine, (decay, drive),
                                            axis=1)      # (B,C,di,N)
        h = h + a_cum * h0[:, None]                       # fold in carry
        y = jnp.einsum("blcn,bln->blc", h, c_sel)         # (B,C,di)
        return h[:, -1], y

    h0 = state.h if state is not None else \
        jnp.zeros((b, di, n), jnp.float32)
    h_end, ys = jax.lax.scan(chunk_body, h0, xcc)
    y = ys.swapaxes(0, 1).reshape(b, nch * MAMBA_CHUNK, di)[:, :l]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["w_out"].astype(x.dtype)
    if return_state:
        # NOTE h_end includes padded steps; padded xc rows are zero ->
        # delta = softplus(bias) ≠ 0, so decays continue on pads.  Exact
        # state comes from re-scanning the unpadded tail:
        if pad:
            h_exact = selective_last_state(p, xc[:, :l], cfg, h0)
        else:
            h_exact = h_end
        k = cfg.ssm.conv_kernel
        conv_tail = jnp.concatenate(
            [state.conv.astype(xin.dtype) if state is not None else
             jnp.zeros((b, k - 1, di), xin.dtype), xin], 1)[:, -(k - 1):]
        return out, MambaState(h=h_exact, conv=conv_tail)
    return out


def selective_last_state(p: Params, xc, cfg: ModelConfig, h0):
    """Exact end state of the selective scan over xc (B, L, di)."""
    decay, drive, _ = _mamba_scan_terms(p, xc, cfg)

    def step(h, t):
        return decay[:, t] * h + drive[:, t], None

    h, _ = jax.lax.scan(step, h0, jnp.arange(xc.shape[1]))
    return h


def mamba_step(p: Params, x, cfg: ModelConfig,
               state: MambaState) -> Tuple[jnp.ndarray, MambaState]:
    """One-token decode with rolling conv buffer + diagonal state update."""
    xin, z = _mamba_inputs(p, x, cfg)                    # (B,1,di)
    buf = jnp.concatenate([state.conv, xin], axis=1)     # (B,K,di)
    w = p["conv_w"].astype(x.dtype)
    xc = jax.nn.silu(jnp.sum(buf * w[None], axis=1, keepdims=True))
    decay, drive, c_sel = _mamba_scan_terms(p, xc, cfg)
    h_new = decay[:, 0] * state.h + drive[:, 0]          # (B,di,N)
    y = jnp.einsum("bcn,bn->bc", h_new, c_sel[:, 0])[:, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["w_out"].astype(x.dtype)
    return out, MambaState(h=h_new, conv=buf[:, 1:])


def init_mamba_state(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> MambaState:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return MambaState(
        h=jnp.zeros((batch, di, s.state_size), jnp.float32),
        conv=jnp.zeros((batch, s.conv_kernel - 1, di), dtype))


# ==========================================================================
# xLSTM block dispatch (pattern string 'm'/'s' cycled over layers)
# ==========================================================================

def xlstm_kind(cfg: ModelConfig, layer_idx: int) -> str:
    pat = cfg.ssm.xlstm_pattern
    return pat[layer_idx % len(pat)]


def init_xlstm_layer(rng, cfg: ModelConfig, layer_idx: int) -> Params:
    if xlstm_kind(cfg, layer_idx) == "s":
        return init_slstm(rng, cfg)
    return init_mlstm(rng, cfg)


def xlstm_forward(p: Params, x, cfg: ModelConfig, layer_idx: int,
                  state=None, return_state: bool = False):
    if xlstm_kind(cfg, layer_idx) == "s":
        return slstm_forward(p, x, cfg, state=state,
                             return_state=return_state)
    return mlstm_forward(p, x, cfg, state=state, return_state=return_state)


def xlstm_step(p: Params, x, cfg: ModelConfig, layer_idx: int, state):
    if xlstm_kind(cfg, layer_idx) == "s":
        return slstm_step(p, x, cfg, state)
    return mlstm_step(p, x, cfg, state)


def init_xlstm_state(cfg: ModelConfig, layer_idx: int, batch: int):
    if xlstm_kind(cfg, layer_idx) == "s":
        return init_slstm_state(cfg, batch)
    return init_mlstm_state(cfg, batch)
