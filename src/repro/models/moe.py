"""Mixture-of-Experts: top-k router + capacity-based grouped expert matmuls.

TPU-native design notes
-----------------------
* Dispatch is **sort + gather** (never scatter): tokens are argsorted by
  expert id, each expert reads a contiguous capacity-C slice, and the combine
  step gathers each token's expert output back by its inverse-permutation
  rank.  Gathers shard cleanly under GSPMD; with the expert axis on the
  ``model`` mesh axis the dispatch/combine lower to all-to-all.
* Expert FFNs are a single batched einsum over stacked weights
  ``(E, d, ff)`` — one big MXU-friendly contraction instead of E separate
  matmuls.
* Capacity ``C = ceil(T·k/E · capacity_factor)`` rounded up to a multiple of
  128 (MXU lane alignment); overflow tokens are dropped (their combine weight
  is zeroed), matching Switch/GShard semantics.
* Covers Mixtral (8e top-2), DeepSeek-V2 (2 shared + 160 routed top-6,
  first layer dense) and LLaDA-MoE styles from one config.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, init_mlp, apply_mlp
from repro.parallel.ctx import constrain


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def capacity(num_tokens: int, cfg: ModelConfig, factor: float = 1.25) -> int:
    e, k = cfg.moe.num_experts, cfg.moe.num_experts_per_tok
    c = int(num_tokens * k * factor / e) + 1
    return max(_round_up(c, 128), 128)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_moe(rng, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, ff = cfg.d_model, m.moe_d_ff
    ks = jax.random.split(rng, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, m.num_experts), scale=0.02),
        # stacked expert weights: SwiGLU gate/up/down per expert
        "w_gate": dense_init(ks[1], (m.num_experts, d, ff)),
        "w_up": dense_init(ks[2], (m.num_experts, d, ff)),
        "w_down": dense_init(ks[3], (m.num_experts, ff, d)),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=ff * m.num_shared_experts)
    return p


# --------------------------------------------------------------------------
# routing
# --------------------------------------------------------------------------

def router_topk(logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """logits (T, E) -> (gates (T, k) normalized, expert_ids (T, k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, ids


def load_balance_loss(logits: jnp.ndarray, ids: jnp.ndarray,
                      num_experts: int) -> jnp.ndarray:
    """Switch-style aux loss: E · Σ_e f_e · P_e  (+ router z-loss)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T, E)
    frac_routed = jnp.mean(
        jax.nn.one_hot(ids, num_experts, dtype=jnp.float32), axis=(0, 1))
    frac_prob = jnp.mean(probs, axis=0)
    aux = num_experts * jnp.sum(frac_routed * frac_prob)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32),
                                             axis=-1)))
    return aux + 1e-3 * z


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _dispatch(p: Params, tokens: jnp.ndarray, cfg: ModelConfig,
              capacity_factor: float, grouped: bool
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (T, d) -> (out (T, d), aux).  The sort-based dispatch core;
    ``grouped=True`` means we run under vmap (shard-local) and must not
    emit sharding constraints (specs would mismatch the batched rank)."""
    m = cfg.moe
    t, d = tokens.shape
    k = m.num_experts_per_tok
    e = m.num_experts
    dt = tokens.dtype

    logits = tokens @ p["router"].astype(dt)                    # (T, E)
    gates, ids = router_topk(logits, k)                         # (T, k)
    aux = load_balance_loss(logits, ids, e) * m.router_aux_coef

    c = capacity(t, cfg, capacity_factor)
    flat_e = ids.reshape(t * k)                                 # (Tk,)
    order = jnp.argsort(flat_e, stable=True)                    # (Tk,)
    rank = jnp.argsort(order, stable=True)                      # inverse perm
    counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=0)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])        # (E,)

    # expert slot grid reads contiguous sorted slices
    slot_idx = offsets[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    slot_valid = jnp.arange(c, dtype=jnp.int32)[None, :] < counts[:, None]
    tok_of_sorted = order // k                                  # (Tk,) token id
    gathered_tok = jnp.take(tok_of_sorted, jnp.clip(slot_idx, 0, t * k - 1),
                            axis=0)                             # (E, C)
    xs = jnp.take(tokens, gathered_tok.reshape(-1), axis=0)
    xs = xs.reshape(e, c, d) * slot_valid[..., None].astype(dt)
    if not grouped:
        # expert parallelism: the dispatch becomes an all-to-all on the
        # model axis when E divides it (guarded inside constrain)
        xs = constrain(xs, ("tp", None, None))

    # batched SwiGLU over experts — single MXU contraction per weight
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["w_gate"].astype(dt),
                                preferred_element_type=jnp.float32).astype(dt))
         * jnp.einsum("ecd,edf->ecf", xs, p["w_up"].astype(dt),
                      preferred_element_type=jnp.float32).astype(dt))
    ys = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt),
                    preferred_element_type=jnp.float32).astype(dt)

    # combine: (token, slot) j sits at expert flat_e[j], position s_of[j]
    s_of = rank - jnp.take(offsets, flat_e)                     # (Tk,)
    in_cap = s_of < c
    flat_out = ys[flat_e, jnp.clip(s_of, 0, c - 1)]             # (Tk, d) gather
    flat_out = flat_out * in_cap[:, None].astype(dt)
    out = jnp.sum(flat_out.reshape(t, k, d)
                  * gates[..., None].astype(dt), axis=1)        # (T, d)
    return out, aux


def moe_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                capacity_factor: float = 1.25
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, L, d) -> (out (B, L, d), aux_loss scalar).

    Sort-based dispatch: gather-only data movement, batched expert einsums.

    SHARD-LOCAL dispatch (§Perf iteration A1): the argsort is global over
    all T tokens, which GSPMD can only realize by replicating the token
    stream (measured: 156 GiB/dev on mixtral × train_4k).  Under an
    activation mesh we therefore group tokens by their (data × seq-shard)
    grid cell — a transpose/reshape that is shard-layout-exact — and vmap
    the dispatch over groups: every sort/scatter becomes shard-local,
    exactly like torch-MoE's per-rank dispatch, and each group meets its
    own capacity independently (standard expert-parallel semantics).
    """
    from repro.parallel.ctx import shard_counts
    m = cfg.moe
    b, l, d = x.shape
    t = b * l
    gd, gm = shard_counts()
    # expert-parallel-capable configs (E divides the model axis — DeepSeek)
    # keep the GLOBAL dispatch: its all-to-all is the efficient path, and
    # grouping would run all E experts per group at padded capacity
    # (measured +80% collective on deepseek train, §Perf B1-refuted).
    # Grouping is for the fallback topology (Mixtral: 8 experts on 16).
    try:
        from repro.parallel.ctx import _STATE as _ctx_state
        msize = (_ctx_state["mesh"].shape["model"]
                 if _ctx_state["mesh"] is not None else 1)
    except Exception:
        msize = 1
    if msize > 1 and m.num_experts % msize == 0:
        gd = gm = 1
    g = gd * gm

    # grouped dispatch pays off only when each group has enough tokens to
    # fill expert capacity tiles; tiny decode batches (T/g « capacity
    # rounding) measured +91% collective from padding — keep those global
    if g > 1 and b % gd == 0 and l % gm == 0 and (t // g) >= 1024:
        xg = x.reshape(gd, b // gd, gm, l // gm, d)
        xg = xg.transpose(0, 2, 1, 3, 4).reshape(g, t // g, d)
        xg = constrain(xg, ("grid", None, None))
        out_g, aux_g = jax.vmap(
            lambda tk: _dispatch(p, tk, cfg, capacity_factor, True))(xg)
        out_g = constrain(out_g, ("grid", None, None))
        out = out_g.reshape(gd, gm, b // gd, l // gm, d) \
            .transpose(0, 2, 1, 3, 4).reshape(b, l, d)
        aux = jnp.mean(aux_g)
    else:
        out, aux = _dispatch(p, x.reshape(t, d), cfg, capacity_factor,
                             False)
        out = out.reshape(b, l, d)

    if m.num_shared_experts:
        out = out + apply_mlp(p["shared"], x.reshape(t, d),
                              cfg).reshape(b, l, d)
    return out, aux
