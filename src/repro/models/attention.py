"""Attention for bidirectional masked-diffusion LMs.

Three attention families, each with a full (train/prefill) path and a cached
single-token decode path:

* **GQA / MHA** — standard grouped-query attention, optional per-head q/k
  RMSNorm (Qwen3) and RoPE variants (standard / half / mrope / none).
* **Sliding-window** — bidirectional band mask ``|i-j| < window`` (the
  diffusion adaptation of Mixtral's causal SWA); the decode path keeps only a
  window-sized KV cache, which is the sub-quadratic route for ``long_500k``.
* **MLA** (DeepSeek-V2) — queries/keys/values factored through low-rank
  latents.  Train path materializes per-head K/V; the decode path runs in
  *absorbed* form against the compressed ``c_kv`` cache (512+64 dims per
  position instead of H·(d_qk+d_v)), which is the whole point of MLA and maps
  directly onto the TPU memory hierarchy (the latent cache stays in HBM, the
  absorbed weight products live in VMEM-resident tiles).

Everything is bidirectional: LLDMs score all masked positions at once, so no
causal mask ever appears here.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (Params, apply_rope, dense_init,
                                 rms_norm_headwise)
from repro.parallel.ctx import constrain


class KVCache(NamedTuple):
    """Frozen-prefix KV cache for semi-AR diffusion decode.

    ``k``/``v``: (B, S, n_kv, hd) for GQA; for MLA ``k`` holds the compressed
    latent (B, S, kv_lora) and ``v`` the rope key (B, S, qk_rope).  ``length``
    is the number of valid positions (static in the dry-run contract).
    """
    k: jnp.ndarray
    v: jnp.ndarray
    length: int


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    if cfg.attention == "mla":
        m = cfg.mla
        ks = jax.random.split(rng, 7)
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = {
            "wq_a": dense_init(ks[0], (d, m.q_lora_rank)),
            "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
            "wq_b": dense_init(ks[1], (m.q_lora_rank, nq * qk)),
            "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim)),
            "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
            "wk_b": dense_init(ks[3], (m.kv_lora_rank, nq * m.qk_nope_head_dim)),
            "wv_b": dense_init(ks[4], (m.kv_lora_rank, nq * m.v_head_dim)),
            "wo": dense_init(ks[5], (nq * m.v_head_dim, d)),
        }
        return p
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, nq * hd)),
        "wk": dense_init(ks[1], (d, nkv * hd)),
        "wv": dense_init(ks[2], (d, nkv * hd)),
        "wo": dense_init(ks[3], (nq * hd, d)),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), jnp.float32)
        p["k_scale"] = jnp.ones((hd,), jnp.float32)
    return p


# --------------------------------------------------------------------------
# masks
# --------------------------------------------------------------------------

def band_mask(q_pos: jnp.ndarray, kv_pos: jnp.ndarray, window: int) -> jnp.ndarray:
    """Bidirectional sliding-window band: attend iff |i-j| < window."""
    diff = q_pos[..., :, None] - kv_pos[..., None, :]
    return jnp.abs(diff) < window


SDPA_CHUNK = 1024   # q-chunk for the memory-efficient long-sequence path


def self_attention(q, k, v, scale: float, window: int = 0,
                   chunk: int = SDPA_CHUNK) -> jnp.ndarray:
    """Full bidirectional self-attention without materializing (L, L).

    Short sequences take the dense path; long ones scan q in chunks of
    ``chunk`` so the live score tensor is (B, H, chunk, L) — the
    memory-efficient jnp equivalent of the Pallas flash kernel (which
    serves the same role on real TPU hardware).  Band masking is computed
    per chunk from index arithmetic, never as an (L, L) bool.
    """
    b, l, h, dh = q.shape
    if l <= chunk:
        mask = band_mask(jnp.arange(l), jnp.arange(l), window) if window \
            else None
        return _sdpa(q, k, v, mask, scale)
    pad = (-l) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nch = q.shape[1] // chunk
    qc = q.reshape(b, nch, chunk, h, dh).swapaxes(0, 1)   # (nch,B,C,H,dh)
    kpos = jnp.arange(l)

    def body(_, xs):
        qch, start = xs
        mask = None
        if window:
            qpos = start + jnp.arange(chunk)
            mask = band_mask(qpos, kpos, window)          # (C, L) only
        return None, _sdpa(qch, k, v, mask, scale)

    starts = jnp.arange(nch, dtype=jnp.int32) * chunk
    _, outs = jax.lax.scan(body, None, (qc, starts))
    out = outs.swapaxes(0, 1).reshape(b, nch * chunk, h, -1)
    return out[:, :l]


def _sdpa(q, k, v, mask: Optional[jnp.ndarray], scale: float) -> jnp.ndarray:
    """q: (B, Lq, H, dh), k/v: (B, Lk, G, dh_{k,v}); grouped heads broadcast.

    Scores accumulate in f32; returns q.dtype.
    """
    b, lq, h, dh = q.shape
    g = k.shape[2]
    rep = h // g
    qg = q.reshape(b, lq, g, rep, dh)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        # mask (Lq, Lk) broadcasts directly; (B, Lq, Lk) gets head axes
        if mask.ndim == 3:
            mask = mask[:, None, None]
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    return out.reshape(b, lq, h, v.shape[-1])


# --------------------------------------------------------------------------
# GQA full + decode
# --------------------------------------------------------------------------

def _project_qkv(p: Params, x, positions, cfg: ModelConfig):
    dt = x.dtype
    b, l, _ = x.shape
    hd, nq, nkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    from repro.parallel.ctx import option
    if option("xgather") and l > 1:
        # gather the (small, bf16) attention input ONCE instead of letting
        # GSPMD all-gather q, k and v separately after projection: one
        # d-wide gather replaces (nq+2·nkv)·hd-wide ones (§Perf C5)
        x = constrain(x, ("dp", None, None))
    q_spec = kv_spec = ("dp", None, "tp", None)
    if option("seq_attn") and l > 1:
        # sequence-parallel attention: q stays seq-sharded (no q gather —
        # each device attends its own seq chunk with ALL heads against
        # gathered k/v).  The natural layout for bidirectional models.
        q_spec = ("dp", "sp", None, None)
        kv_spec = ("dp", None, None, None)
    q = constrain((x @ p["wq"].astype(dt)).reshape(b, l, nq, hd), q_spec)
    k = constrain((x @ p["wk"].astype(dt)).reshape(b, l, nkv, hd), kv_spec)
    v = constrain((x @ p["wv"].astype(dt)).reshape(b, l, nkv, hd), kv_spec)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_scale"])
        k = rms_norm_headwise(k, p["k_scale"])
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    return q, k, v


def gqa_forward(p: Params, x, positions, cfg: ModelConfig,
                attn_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full bidirectional attention over x (B, L, d)."""
    q, k, v = _project_qkv(p, x, positions, cfg)
    out = self_attention(q, k, v, cfg.head_dim ** -0.5,
                         window=cfg.sliding_window)
    out = constrain(out.reshape(*x.shape[:2], -1), ("dp", None, "tp"))
    # NOTE (§Perf C3, refuted & reverted): forcing the row-parallel product
    # to the sequence-parallel layout here (reduce-scatter instead of
    # all-reduce) measured neutral on qwen3 prefill and +43% collective on
    # deepseek train — GSPMD's own choice is better; leave it free.
    return out @ p["wo"].astype(x.dtype)


def gqa_decode(p: Params, x, positions, cfg: ModelConfig,
               cache: KVCache) -> Tuple[jnp.ndarray, KVCache]:
    """One new token (B, 1, d) against a frozen cache of capacity S.

    The new k/v are written IN PLACE (``dynamic_update_slice`` + buffer
    donation — no concat copy of a 32k/500k cache per layer), then the
    token attends bidirectionally over the valid prefix.  Sliding-window
    configs keep a window-sized ring buffer, the O(W) route for long_500k.
    """
    q, k_new, v_new = _project_qkv(p, x, positions, cfg)
    pos0 = positions[0, 0] if positions.ndim == 2 else positions[0, 0, 0]
    cap = cache.k.shape[1]
    slot = (pos0 % cap) if cfg.sliding_window else jnp.minimum(pos0, cap - 1)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype),
                                            slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype),
                                            slot, axis=1)
    valid = jnp.arange(cap) <= pos0          # ring buffer: all valid once warm
    out = _sdpa(q, k.astype(x.dtype), v.astype(x.dtype), valid[None, None],
                cfg.head_dim ** -0.5)
    out = out.reshape(*x.shape[:2], -1) @ p["wo"].astype(x.dtype)
    return out, KVCache(k=k, v=v, length=cache.length + 1)


def gqa_window(p: Params, x, positions, cfg: ModelConfig, cache: KVCache,
               extend: bool = False) -> Tuple[jnp.ndarray, KVCache]:
    """A W-token window attends [valid frozen prefix | itself] (Fast-dLLM-
    style cached semi-AR decoding; sampler scale, so the concat is cheap).

    ``extend=True`` additionally writes the window's k/v into the cache at
    the current valid length (used once per committed block)."""
    dt = x.dtype
    w = x.shape[1]
    q, k_new, v_new = _project_qkv(p, x, positions, cfg)
    cap = cache.k.shape[1]
    length = cache.length
    k = jnp.concatenate([cache.k.astype(dt), k_new], axis=1)
    v = jnp.concatenate([cache.v.astype(dt), v_new], axis=1)
    valid = jnp.concatenate([jnp.arange(cap) < length,
                             jnp.ones((w,), bool)])
    out = _sdpa(q, k, v, valid[None, None], cfg.head_dim ** -0.5)
    out = out.reshape(*x.shape[:2], -1) @ p["wo"].astype(x.dtype)
    if extend:
        k2 = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), length, axis=1)
        v2 = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), length, axis=1)
        cache = KVCache(k=k2, v=v2, length=length + w)
    return out, cache


def mla_window(p: Params, x, positions, cfg: ModelConfig, cache: KVCache,
               extend: bool = False) -> Tuple[jnp.ndarray, KVCache]:
    """Window attention against the compressed MLA latent cache (per-head
    K/V reconstructed from the valid latents — fine at sampler scale)."""
    m = cfg.mla
    dt = x.dtype
    b, w, _ = x.shape
    nq = cfg.num_heads
    q_nope, q_rope, c_new, kr_new = _mla_latents(p, x, positions, cfg)
    cap = cache.k.shape[1]
    length = cache.length
    c_all = jnp.concatenate([cache.k.astype(dt), c_new], axis=1)
    kr_all = jnp.concatenate([cache.v.astype(dt), kr_new], axis=1)
    s = cap + w
    k_nope = (c_all @ p["wk_b"].astype(dt)).reshape(b, s, nq,
                                                    m.qk_nope_head_dim)
    vv = (c_all @ p["wv_b"].astype(dt)).reshape(b, s, nq, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                  (b, s, nq, m.qk_rope_head_dim))], axis=-1)
    valid = jnp.concatenate([jnp.arange(cap) < length,
                             jnp.ones((w,), bool)])
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = _sdpa(q, k, vv, valid[None, None], scale)
    out = out.reshape(b, w, -1) @ p["wo"].astype(dt)
    if extend:
        c2 = jax.lax.dynamic_update_slice_in_dim(
            cache.k, c_new.astype(cache.k.dtype), length, axis=1)
        kr2 = jax.lax.dynamic_update_slice_in_dim(
            cache.v, kr_new.astype(cache.v.dtype), length, axis=1)
        cache = KVCache(k=c2, v=kr2, length=length + w)
    return out, cache


def attention_window(p: Params, x, positions, cfg: ModelConfig,
                     cache: KVCache, extend: bool = False
                     ) -> Tuple[jnp.ndarray, KVCache]:
    if cfg.attention == "mla":
        return mla_window(p, x, positions, cfg, cache, extend)
    return gqa_window(p, x, positions, cfg, cache, extend)


# --------------------------------------------------------------------------
# fixed-shape block cache (cache_policy = prefix | dual)
# --------------------------------------------------------------------------
#
# Unlike the shrinking-window path above (variable cache length, host-side
# valid-length bookkeeping), these two entry points keep every shape static
# so they can ride the fused drivers: the cache always covers ALL ``total``
# positions of the canvas, and the live window writes its fresh K/V into a
# functional copy at a *traced* offset.  No validity mask is needed —
# attention is bidirectional and every column is context: cached outside
# the window, freshly recomputed inside it.

def gqa_capture(p: Params, x, positions, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, KVCache]:
    """Full attention that also returns the K/V it computed — the
    prefill/refresh op of the fixed-shape block cache."""
    q, k, v = _project_qkv(p, x, positions, cfg)
    out = self_attention(q, k, v, cfg.head_dim ** -0.5,
                         window=cfg.sliding_window)
    out = constrain(out.reshape(*x.shape[:2], -1), ("dp", None, "tp"))
    # length is an array so the cache stacks/slices cleanly across the
    # per-group layer axis (it is never consulted: the cache is always full)
    return (out @ p["wo"].astype(x.dtype),
            KVCache(k=k, v=v, length=jnp.int32(x.shape[1])))


def gqa_cached(p: Params, x, positions, cfg: ModelConfig, cache: KVCache,
               win_start) -> jnp.ndarray:
    """A W-row live window attends over the full fixed-length cache with
    its own fresh K/V scattered in at traced ``win_start`` (read-only with
    respect to the cache — refreshes go through ``gqa_capture``)."""
    dt = x.dtype
    w = x.shape[1]
    q, k_new, v_new = _project_qkv(p, x, positions, cfg)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k.astype(dt), k_new,
                                            win_start, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v.astype(dt), v_new,
                                            win_start, axis=1)
    mask = None
    if cfg.sliding_window:
        mask = band_mask(win_start + jnp.arange(w),
                         jnp.arange(cache.k.shape[1]), cfg.sliding_window)
    out = _sdpa(q, k, v, mask, cfg.head_dim ** -0.5)
    out = out.reshape(*x.shape[:2], -1)
    return out @ p["wo"].astype(dt)


def mla_capture(p: Params, x, positions, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, KVCache]:
    """Materialized MLA forward returning the latent cache (c_kv, k_rope)."""
    m = cfg.mla
    dt = x.dtype
    b, l, _ = x.shape
    nq = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _mla_latents(p, x, positions, cfg)
    k_nope = (c_kv @ p["wk_b"].astype(dt)).reshape(b, l, nq,
                                                   m.qk_nope_head_dim)
    v = (c_kv @ p["wv_b"].astype(dt)).reshape(b, l, nq, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, l, nq, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = self_attention(q, k, v, scale)
    return (out.reshape(b, l, -1) @ p["wo"].astype(dt),
            KVCache(k=c_kv, v=k_rope, length=jnp.int32(l)))


def mla_cached(p: Params, x, positions, cfg: ModelConfig, cache: KVCache,
               win_start) -> jnp.ndarray:
    """Live window against the fixed-length MLA latent cache (per-head K/V
    reconstructed from all latents — fine at sampler scale)."""
    m = cfg.mla
    dt = x.dtype
    b, w, _ = x.shape
    nq = cfg.num_heads
    q_nope, q_rope, c_new, kr_new = _mla_latents(p, x, positions, cfg)
    c_all = jax.lax.dynamic_update_slice_in_dim(cache.k.astype(dt), c_new,
                                                win_start, axis=1)
    kr_all = jax.lax.dynamic_update_slice_in_dim(cache.v.astype(dt), kr_new,
                                                 win_start, axis=1)
    s = c_all.shape[1]
    k_nope = (c_all @ p["wk_b"].astype(dt)).reshape(b, s, nq,
                                                    m.qk_nope_head_dim)
    vv = (c_all @ p["wv_b"].astype(dt)).reshape(b, s, nq, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                  (b, s, nq, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = _sdpa(q, k, vv, None, scale)
    return out.reshape(b, w, -1) @ p["wo"].astype(dt)


def attention_capture(p: Params, x, positions, cfg: ModelConfig
                      ) -> Tuple[jnp.ndarray, KVCache]:
    if cfg.attention == "mla":
        return mla_capture(p, x, positions, cfg)
    return gqa_capture(p, x, positions, cfg)


def attention_cached(p: Params, x, positions, cfg: ModelConfig,
                     cache: KVCache, win_start) -> jnp.ndarray:
    if cfg.attention == "mla":
        return mla_cached(p, x, positions, cfg, cache, win_start)
    return gqa_cached(p, x, positions, cfg, cache, win_start)


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# --------------------------------------------------------------------------

def _mla_latents(p: Params, x, positions, cfg: ModelConfig):
    """Shared front half: query heads + compressed kv latent + rope key."""
    m = cfg.mla
    dt = x.dtype
    b, l, _ = x.shape
    nq = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = rms_norm_headwise(x @ p["wq_a"].astype(dt), p["q_norm"])
    q = constrain((q_lat @ p["wq_b"].astype(dt)).reshape(b, l, nq, qk),
                  ("dp", None, "tp", None))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg, head_dim=m.qk_rope_head_dim)

    kv = x @ p["wkv_a"].astype(dt)                     # (B, L, kv_lora + rope)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm_headwise(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg,
                        head_dim=m.qk_rope_head_dim)[:, :, 0]   # shared head
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p: Params, x, positions, cfg: ModelConfig,
                attn_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Materialized MLA for train/prefill (per-head K/V from the latent)."""
    m = cfg.mla
    dt = x.dtype
    b, l, _ = x.shape
    nq = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _mla_latents(p, x, positions, cfg)
    k_nope = constrain((c_kv @ p["wk_b"].astype(dt))
                       .reshape(b, l, nq, m.qk_nope_head_dim),
                       ("dp", None, "tp", None))
    v = constrain((c_kv @ p["wv_b"].astype(dt))
                  .reshape(b, l, nq, m.v_head_dim),
                  ("dp", None, "tp", None))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, l, nq, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = self_attention(q, k, v, scale)
    return out.reshape(b, l, -1) @ p["wo"].astype(dt)


def mla_decode(p: Params, x, positions, cfg: ModelConfig,
               cache: KVCache) -> Tuple[jnp.ndarray, KVCache]:
    """Absorbed-form MLA decode against the compressed latent cache.

    cache.k = c_kv (B, S, kv_lora), cache.v = k_rope (B, S, qk_rope).
    Scores:  q_nope·W_UKᵀ ⟶ latent-space query (per head), dotted with c_kv;
    Output:  attn·c_kv absorbed through W_UV.  Never materializes per-head
    K/V over the 32k/500k cache — the decisive memory saving.
    """
    m = cfg.mla
    dt = x.dtype
    b, l, _ = x.shape
    nq = cfg.num_heads
    q_nope, q_rope, c_new, kr_new = _mla_latents(p, x, positions, cfg)
    pos0 = positions[0, 0] if positions.ndim == 2 else positions[0, 0, 0]
    cap = cache.k.shape[1]
    slot = jnp.minimum(pos0, cap - 1)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache.k, c_new.astype(cache.k.dtype), slot, axis=1).astype(dt)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache.v, kr_new.astype(cache.v.dtype), slot, axis=1).astype(dt)
    valid = (jnp.arange(cap) <= pos0).astype(jnp.float32)

    # absorb W_UK into the query: q_lat (B,1,H,r)
    wk_b = p["wk_b"].astype(dt).reshape(m.kv_lora_rank, nq, m.qk_nope_head_dim)
    q_lat = jnp.einsum("blhd,rhd->blhr", q_nope, wk_b,
                       preferred_element_type=jnp.float32).astype(dt)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("blhr,bsr->bhls", q_lat, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("blhd,bsd->bhls", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    scores = jnp.where(valid[None, None, None] > 0, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhls,bsr->blhr", w, c_kv,
                       preferred_element_type=jnp.float32).astype(dt)
    wv_b = p["wv_b"].astype(dt).reshape(m.kv_lora_rank, nq, m.v_head_dim)
    out = jnp.einsum("blhr,rhd->blhd", o_lat, wv_b,
                     preferred_element_type=jnp.float32).astype(dt)
    out = out.reshape(b, l, -1) @ p["wo"].astype(dt)
    return out, KVCache(k=c_kv, v=k_rope, length=cache.length + 1)


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def attention_forward(p: Params, x, positions, cfg: ModelConfig,
                      attn_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    if cfg.attention == "mla":
        return mla_forward(p, x, positions, cfg, attn_mask)
    return gqa_forward(p, x, positions, cfg, attn_mask)


def attention_decode(p: Params, x, positions, cfg: ModelConfig,
                     cache: KVCache) -> Tuple[jnp.ndarray, KVCache]:
    if cfg.attention == "mla":
        return mla_decode(p, x, positions, cfg, cache)
    return gqa_decode(p, x, positions, cfg, cache)


def init_cache(cfg: ModelConfig, batch: int, length: int,
               dtype=jnp.bfloat16,
               valid_length: Optional[int] = None) -> KVCache:
    """Allocate (or spec) the decode cache for one layer.

    ``valid_length`` overrides the initial valid count (0 for the cached
    sampler, which fills the buffer block by block; default = ``length``,
    the dry-run contract of a fully warmed cache)."""
    vl = length if valid_length is None else valid_length
    if cfg.attention == "mla":
        m = cfg.mla
        return KVCache(k=jnp.zeros((batch, length, m.kv_lora_rank), dtype),
                       v=jnp.zeros((batch, length, m.qk_rope_head_dim), dtype),
                       length=vl)
    eff = min(length, cfg.sliding_window) if cfg.sliding_window else length
    return KVCache(
        k=jnp.zeros((batch, eff, cfg.num_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, eff, cfg.num_kv_heads, cfg.head_dim), dtype),
        length=vl)
