"""mixtral-8x22b — sparse MoE with sliding-window attention [arXiv:2401.04088].

56 layers, d_model=6144, 48 heads (GQA kv=8), per-expert d_ff=16384,
vocab=32768, 8 experts top-2.  SWA makes this arch eligible for long_500k.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,                     # dense-equivalent hidden (experts use moe_d_ff)
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2, moe_d_ff=16384),
    max_seq_len=65536,
    remat="block",
)
