"""hymba-1.5b — hybrid-head model: attention and mamba heads in parallel
within every block, outputs fused [arXiv:2411.13676].

32 layers, d_model=1600, 25 attn heads (GQA kv=5), d_ff=5504, vocab=32001,
ssm_state=16.  Hybrid -> long_500k runs (SSM state + sliding-window attn).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,          # Hymba uses SWA on most attn layers
    hybrid_ssm_heads=8,
    ssm=SSMConfig(state_size=16, conv_kernel=4, expand=2, num_ssm_heads=8),
    max_seq_len=524288,
    remat="block",
)
