"""Config registry: ``get_config(name)`` / ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (DecodeConfig, DegradeConfig, EncDecConfig,
                                ExecutionConfig, LadderRung, MLAConfig,
                                ModelConfig, MoEConfig, RouterConfig,
                                SSMConfig, ServerConfig, SupervisorConfig,
                                TrainConfig, default_block_size)

# arch id -> module (one file per assigned architecture + the paper's own)
_MODULES: Dict[str, str] = {
    "whisper-medium":   "repro.configs.whisper_medium",
    "mixtral-8x22b":    "repro.configs.mixtral_8x22b",
    "stablelm-12b":     "repro.configs.stablelm_12b",
    "stablelm-3b":      "repro.configs.stablelm_3b",
    "qwen3-14b":        "repro.configs.qwen3_14b",
    "xlstm-125m":       "repro.configs.xlstm_125m",
    "chatglm3-6b":      "repro.configs.chatglm3_6b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "hymba-1.5b":       "repro.configs.hymba_1_5b",
    "qwen2-vl-72b":     "repro.configs.qwen2_vl_72b",
    "llada-8b":         "repro.configs.llada_8b",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "llada-8b"]


def get_config(name: str) -> ModelConfig:
    if name.endswith("-tiny"):
        return get_config(name[: -len("-tiny")]).reduced()
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def list_configs() -> List[str]:
    return sorted(_MODULES)


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "EncDecConfig",
    "DecodeConfig", "ExecutionConfig", "TrainConfig", "ServerConfig",
    "RouterConfig",
    "SupervisorConfig", "DegradeConfig", "LadderRung",
    "default_block_size",
    "get_config", "list_configs", "ASSIGNED_ARCHS",
]
