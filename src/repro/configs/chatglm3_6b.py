"""chatglm3-6b — dense GQA decoder with 2d (half-dim) RoPE [arXiv:2406.12793].

28 layers, d_model=4096, 32 heads (GQA kv=2), d_ff=13696, vocab=65024.
RoPE is applied to half of each head dim (GLM's 2d rotary).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    arch_type="dense",
    source="arXiv:2406.12793",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope="half",
    max_seq_len=32768,
    remat="block",
)
