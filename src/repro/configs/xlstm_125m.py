"""xlstm-125m — recurrent xLSTM stack (sLSTM + mLSTM blocks) [arXiv:2405.04517].

12 layers, d_model=768, 4 heads, vocab=50304, d_ff=0 (projections live inside
the xLSTM blocks).  Attention-free -> long_500k runs with O(1) state decode.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    source="arXiv:2405.04517",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    attention="none",
    rope="none",
    ssm=SSMConfig(state_size=16, expand=2, num_ssm_heads=4,
                  xlstm_pattern="mmmmmms"),   # sLSTM every 7th block (xLSTM[7:1])
    max_seq_len=524288,
    remat="block",
)
