"""stablelm-12b — dense GQA decoder [hf:stabilityai/stablelm-2-1_6b family].

40 layers, d_model=5120, 32 heads (GQA kv=8), d_ff=13824, vocab=100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    max_seq_len=32768,
    remat="block",
)
