"""Config dataclasses for the foresee framework.

A single ``ModelConfig`` describes every architecture in the assigned pool; the
block assembler (``repro.models.blocks``) reads the flags it needs.  Configs are
frozen dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0              # routed experts (0 = dense)
    num_experts_per_tok: int = 0      # top-k
    num_shared_experts: int = 0       # DeepSeek-style always-on experts
    moe_d_ff: int = 0                 # per-expert hidden size
    first_k_dense: int = 0            # leading layers that stay dense (DeepSeek-V2: 1)
    router_aux_coef: float = 0.01     # load-balance loss weight


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """xLSTM / Mamba-family knobs."""
    state_size: int = 16              # per-head/channel recurrent state (Hymba: 16)
    conv_kernel: int = 4              # depthwise conv width (mamba)
    expand: int = 2                   # inner expansion factor
    # xLSTM block pattern: 'm' = mLSTM, 's' = sLSTM, repeated/cycled over layers.
    xlstm_pattern: str = "mmmmmms"    # xLSTM-125m style: mostly mLSTM w/ periodic sLSTM
    num_ssm_heads: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 0
    encoder_seq: int = 0              # e.g. Whisper: 1500 audio frames
    frontend: str = "none"            # 'audio_stub' | 'vision_stub' | 'none'
    num_patch_tokens: int = 0         # VLM: stub patch embeddings prepended


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""                  # citation for the dims

    head_dim: int = 0                 # 0 -> d_model // num_heads
    attention: str = "gqa"            # gqa | mla | none (pure ssm)
    rope: str = "standard"            # standard | half (ChatGLM 2d) |
                                      # mrope | sinusoidal | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # M-RoPE split of head_dim/2 (t, h, w)
    qk_norm: bool = False
    sliding_window: int = 0           # 0 = full attention
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "silu"                 # silu (SwiGLU) | gelu (plain MLP)
    tie_embeddings: bool = False

    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    # hybrid (Hymba): fraction of heads that are SSM heads, run in parallel with attn
    hybrid_ssm_heads: int = 0

    # diffusion
    mask_token_id: int = -1           # -1 -> vocab_size - 1 (reserved)
    max_seq_len: int = 4096
    dtype: str = "bfloat16"
    remat: str = "none"               # none | block  (checkpoint each
                                      # block in train fwd)
    unroll: bool = False              # unroll layers instead of lax.scan
                                      # (dry-run cost extrapolation: XLA
                                      # counts a scan body once)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.mask_token_id < 0:
            object.__setattr__(self, "mask_token_id", self.vocab_size - 1)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    # -- derived ----------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encdec is not None and self.encdec.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """True iff long-context decode (long_500k) is admissible."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings included once)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k experts only)."""
        return _param_count(self, active_only=True)

    def reduced(self, **over) -> "ModelConfig":
        """The smoke-test variant: same family, tiny dims (spec: <=2 layers,
        d_model<=512, <=4 experts)."""
        small: dict = dict(
            name=self.name + "-tiny",
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=min(self.max_seq_len, 128),
            head_dim=0,
            mask_token_id=-1,
            dtype="float32",
            remat="none",
        )
        small["num_kv_heads"] = min(self.num_kv_heads, small["num_heads"])
        if small["num_heads"] % small["num_kv_heads"]:
            small["num_kv_heads"] = 1
        if self.is_moe:
            small["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                num_experts_per_tok=min(self.moe.num_experts_per_tok, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                moe_d_ff=min(self.moe.moe_d_ff, 256),
                first_k_dense=min(self.moe.first_k_dense, 1),
            )
        if self.mla is not None:
            small["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                                     qk_nope_head_dim=32, qk_rope_head_dim=16,
                                     v_head_dim=32)
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, state_size=min(self.ssm.state_size, 16),
                num_ssm_heads=min(self.ssm.num_ssm_heads, 2))
        if self.encdec is not None:
            small["encdec"] = dataclasses.replace(
                self.encdec,
                encoder_layers=min(self.encdec.encoder_layers, 2),
                encoder_seq=min(self.encdec.encoder_seq, 32) or 0,
                num_patch_tokens=min(self.encdec.num_patch_tokens, 16))
        if self.hybrid_ssm_heads:
            small["hybrid_ssm_heads"] = 1
        if self.sliding_window:
            small["sliding_window"] = 32
        if self.mrope_sections:
            hd = small["d_model"] // small["num_heads"]
            small["mrope_sections"] = (hd // 4, hd // 8, hd // 8)
        small.update(over)
        return dataclasses.replace(self, **small)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    n_q, n_kv = cfg.num_heads, cfg.num_kv_heads
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.attention == "mla" and cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        attn = (d * m.q_lora_rank + m.q_lora_rank * n_q * qk
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                + n_q * m.v_head_dim * d)
    else:
        attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
    if cfg.arch_type == "ssm":
        # xLSTM block: qkv-style projections + gates; approximate with expand factor
        e = cfg.ssm.expand if cfg.ssm else 2
        per_layer = 2 * d * (e * d) + (e * d) * d + 4 * d
        return embed + cfg.num_layers * per_layer
    def ffn_params(dff):
        mult = 3 if cfg.act == "silu" else 2      # SwiGLU has gate+up+down
        return mult * d * dff
    per_layer = attn + 2 * d  # norms
    if cfg.hybrid_ssm_heads and cfg.ssm:
        e = cfg.ssm.expand
        per_layer += d * (e * d) + (e * d) * d   # parallel SSM path
    total = 0
    for li in range(cfg.num_layers):
        layer = per_layer
        if cfg.is_moe and li >= cfg.moe.first_k_dense:
            n_routed = (cfg.moe.num_experts_per_tok if active_only
                        else cfg.moe.num_experts)
            layer += ((n_routed + cfg.moe.num_shared_experts)
                      * ffn_params(cfg.moe.moe_d_ff))
            layer += d * cfg.moe.num_experts   # router
        elif cfg.d_ff:
            layer += ffn_params(cfg.d_ff)
        total += layer
    if cfg.is_encdec and cfg.encdec:
        # encoder layers (full attn + ffn) + per-decoder-layer cross attention
        enc = cfg.encdec.encoder_layers * (attn + ffn_params(cfg.d_ff) + 2 * d)
        total += enc + cfg.num_layers * attn
    return embed + total


CACHE_POLICIES = ("none", "prefix", "dual")
CACHE_REFRESH_MODES = ("block", "off")


@dataclass(frozen=True)
class ExecutionConfig:
    """The validated execution surface of a :class:`DecodeConfig`.

    Groups the driver-selection knobs (``fused_loop`` / ``fused_blocks`` /
    ``use_pallas_kernel``) with the KV-cache policy axis
    (``cache_policy`` / ``cache_refresh``) behind one object that
    validates on construction.  ``DecodeConfig`` keeps the same knobs as
    flat fields (so ``dataclasses.replace(dcfg, fused_loop=...)`` keeps
    working everywhere, and the frozen dataclass stays the hashable unit
    that keys jit caches and serving bucket keys) and exposes the grouped
    view as ``dcfg.execution``; constructing a ``DecodeConfig`` always
    constructs — and therefore validates — this sub-config, so an
    invalid combination is rejected at the boundary it crosses
    (``ServingEngine.submit`` → 400, ``Decoder.__init__``), never deep
    inside a trace.
    """
    fused_loop: bool = True
    fused_blocks: bool = True
    use_pallas_kernel: Optional[bool] = None
    cache_policy: str = "none"
    cache_refresh: str = "block"

    def __post_init__(self):
        if self.cache_policy not in CACHE_POLICIES:
            raise ValueError(
                f"unknown cache_policy {self.cache_policy!r}; "
                f"expected one of {CACHE_POLICIES}")
        if self.cache_refresh not in CACHE_REFRESH_MODES:
            raise ValueError(
                f"unknown cache_refresh {self.cache_refresh!r}; "
                f"expected one of {CACHE_REFRESH_MODES}")
        if self.cache_policy == "dual" and self.cache_refresh == "off":
            raise ValueError(
                "cache_policy='dual' requires cache_refresh='block': the "
                "dual cache freezes committed blocks AND the masked "
                "suffix, so skipping block-boundary refreshes would "
                "decode every block against the prefill-time canvas")

    @property
    def cached(self) -> bool:
        return self.cache_policy != "none"


@dataclass(frozen=True)
class DecodeConfig:
    """Sampler / strategy hyperparameters (paper §5.1 defaults)."""
    gen_length: int = 256
    block_size: int = 64
    steps: int = 256                   # T
    strategy: str = "fdm"              # random|probability|margin|entropy|
                                       # eb|wino|fdm|fdm_a|wino_r|extrapolate
    temperature: float = 0.0
    # execution (grouped + validated view: ``dcfg.execution``)
    fused_loop: bool = True            # device-resident lax.while_loop block
                                       # driver (core/loop.py); False = the
                                       # legacy host step loop (debugging /
                                       # A/B: benchmarks/loop_overhead.py)
    fused_blocks: bool = True          # fuse the OUTER block loop too: one
                                       # lax.scan over blocks = one compiled
                                       # dispatch per request.  False =
                                       # per-block dispatches, for debugging
                                       # and block streaming.  Only
                                       # meaningful with fused_loop=True.
    use_pallas_kernel: Optional[bool] = None
                                       # route score_logits through the fused
                                       # Pallas confidence kernel; None =
                                       # auto (TPU only — interpret mode on
                                       # CPU costs more than it saves)
    cache_policy: str = "none"         # none | prefix | dual — the KV-cache
                                       # axis (DESIGN.md "The KV cache").
                                       # prefix: freeze prompt K/V, keep the
                                       # whole generation region live (exact
                                       # within the generation).  dual:
                                       # Fast-dLLM-style — freeze prompt,
                                       # committed blocks AND masked suffix;
                                       # only the active block is live
                                       # (approximate within a block).
    cache_refresh: str = "block"       # block | off — recapture the cache
                                       # with one full forward at each block
                                       # boundary; 'off' (prefix only) keeps
                                       # the prefill-time cache for the
                                       # whole request
    # FDM (Algorithm 1)
    k: int = 2                         # search width K
    gamma: float = 0.6                 # dynamic pruning threshold
    # FDM-A (Algorithm 2)
    k1: int = 2
    gamma1: float = 0.5
    eta1: float = 0.8
    eta2: float = 0.7
    n_max: int = 8                     # N: decode-count upper bound
    # EB baseline
    eb_threshold: float = 0.5
    # WINO baseline
    wino_tau1: float = 0.7
    wino_tau2: float = 0.9
    # wino_r (carry-ful WINO revocation, core/wino.py): each step's
    # commits stay *pending* in the carry and are re-verified against the
    # NEXT step's regular forward (one forward per step — the stateless
    # "wino" baseline pays a second verify forward every step); a pending
    # token whose re-scored probability falls below `wino_revoke_tau` is
    # re-masked and re-decoded, at most `wino_revoke_budget` times per
    # example per request.  The threshold is deliberately FAR below the
    # commit-time confidence scale (and below the stateless baseline's
    # τ₂): masked-diffusion training supervises masked positions only, so
    # re-scores at already-committed (unmasked) positions are noisy —
    # measured on the sum testbed, stable commits re-score ≥ 0.79 while
    # genuine contradictions re-score ≤ 0.2, so 0.3 revokes only the
    # confident contradictions.  Keep the budget well under block_size·3:
    # the block safety cap is block_size·4 and each revocation can add a
    # step.
    wino_revoke_tau: float = 0.3
    wino_revoke_budget: int = 8
    # extrapolate (confidence extrapolation / local determinism
    # propagation, core/extrapolate.py): per position the carry tracks a
    # confidence EMA (decay `extrap_beta`), its slope, and the last
    # argmax candidate; once every example could fill its commit width
    # with positions whose trajectory `ema + horizon·slope` crosses
    # `extrap_tau` (after ≥ `extrap_min_obs` observations), the step
    # commits from the carry and SKIPS the model forward entirely
    # (surfaced as SampleStats.skipped_forwards).
    extrap_tau: float = 0.92
    extrap_beta: float = 0.5
    extrap_horizon: float = 2.0
    extrap_min_obs: int = 2
    # observability (DESIGN.md "Observability"): record per-step decode
    # telemetry on device — commit step/confidence per position,
    # commit/revocation counts, forward-skip flags, FDM-A phase — in a
    # fixed-shape TraceBuffer riding the strategy carry
    # (core/tracebuffer.py), read back with ONE device_get per decode.
    # Off by default: the disabled path never sees the buffer (the
    # strategy is only wrapped when trace=True, and the dcfg is part of
    # every runner-cache subkey), so trace=off decodes stay bit-identical
    # and share their compiled executables with pre-trace configs.
    trace: bool = False

    def __post_init__(self):
        # Constructing the grouped view validates the execution knobs, so
        # every DecodeConfig ever built (including dataclasses.replace at
        # the serving boundary) carries a coherent execution surface.
        _ = self.execution

    @property
    def execution(self) -> ExecutionConfig:
        """Grouped, validated execution sub-config (see ExecutionConfig)."""
        return ExecutionConfig(
            fused_loop=self.fused_loop, fused_blocks=self.fused_blocks,
            use_pallas_kernel=self.use_pallas_kernel,
            cache_policy=self.cache_policy, cache_refresh=self.cache_refresh)


def default_block_size(gen_length: int) -> int:
    """Largest block ≤ gen_length/2 that divides gen_length (semi-AR
    geometry requires ``gen_length % block_size == 0``; the naive
    ``gen_length // 2`` breaks odd lengths).  Falls back to 1
    (per-token blocks) for primes."""
    return next((b for b in range(gen_length // 2, 1, -1)
                 if gen_length % b == 0), 1)


@dataclass(frozen=True)
class SupervisorConfig:
    """Engine supervision (``repro.serving.supervisor``) knobs.

    The async scheduler runs every batch under this policy: decode
    failures are caught at the batch boundary, transient ones retried
    with capped exponential backoff, persistent ones bisected until the
    poison request is isolated and quarantined (it alone gets a terminal
    ``error`` event; its co-batched neighbours are re-queued and
    survive).  Engine-fatal failures (OOM-shaped errors, watchdog
    timeouts) feed a sliding-window crash counter; at
    ``breaker_threshold`` crashes inside ``breaker_window_s`` the
    circuit breaker trips and the engine is rebuilt through the router's
    hot-swap path while ``/healthz`` reports the model degraded (until
    the next clean batch completes).
    """
    max_retries: int = 2               # same-batch retries for transient
                                       # failures before bisection; also the
                                       # per-request re-queue cap on the
                                       # engine-fatal path
    backoff_base_s: float = 0.05       # retry delay: base * 2^(attempt-1),
    backoff_cap_s: float = 2.0         # capped here, with seeded jitter
    watchdog_s: float = 0.0            # per-BLOCK decode timeout (0 = off).
                                       # A block that exceeds it abandons
                                       # the batch: engine-fatal (the engine
                                       # may be wedged), requests re-queued
    breaker_threshold: int = 3         # engine-fatal crashes inside the
    breaker_window_s: float = 60.0     # window that trip the breaker
    drain_deadline_s: float = 5.0      # graceful-drain bound: queued work
                                       # gets this long to finish before the
                                       # remainder is shut down


@dataclass(frozen=True)
class LadderRung:
    """One graceful-degradation rung: when queue depth reaches
    ``at_depth`` (as a fraction of ``max_queue_depth``), effective steps
    are scaled by ``steps_scale``.  Fewer denoising steps over the same
    ``gen_length`` means MORE tokens committed in parallel per step —
    the cheapen-before-shed response ParallelBench's workload-dependent
    quality/latency frontier calls for."""
    at_depth: float
    steps_scale: float


@dataclass(frozen=True)
class DegradeConfig:
    """Graceful-degradation ladder (scheduler admission path).

    Under queue-depth or deadline-headroom pressure the scheduler
    progressively cheapens per-request effective configs before
    resorting to 429: rung 1 halves the step budget, rung 2 quarters it
    (never below one step per block).  The default rungs come from the
    recorded frontier curves: BENCH_ablation_carry shows the sum testbed
    holds EM within ~2 points at half the forwards, and
    BENCH_decode_loop shows steps/sec is step-budget-linear — so halving
    steps roughly halves queue drain time, which is the lever that keeps
    the 429 count down at pressure (see ``benchmarks/serving_load.py``'s
    degraded-mode scenario).
    """
    enabled: bool = True
    rungs: Tuple[LadderRung, ...] = (LadderRung(at_depth=0.5,
                                                steps_scale=0.5),
                                     LadderRung(at_depth=0.8,
                                                steps_scale=0.25))


@dataclass(frozen=True)
class ServerConfig:
    """Async serving front end (``repro.serving.server``) knobs.

    Admission control is three-sided: ``max_queue_depth`` bounds the
    per-model engine queue (submits beyond it are rejected with HTTP 429
    — closed-loop clients back off instead of growing an unbounded
    queue), ``default_deadline_s`` expires requests that sit QUEUED
    longer than their deadline (they are dropped at batch-selection time,
    never decoded, and their streams get a terminal ``expired`` event),
    and the ``degrade`` ladder cheapens per-request step budgets under
    pressure BEFORE the queue fills (shed steps before shedding
    requests).  All act at the scheduling grain of blockwise diffusion
    decoding — between batches — because a running batch is
    batch-synchronous and cannot be preempted mid-decode.
    """
    host: str = "127.0.0.1"
    port: int = 8000                   # 0 = pick an ephemeral port
    max_queue_depth: int = 64          # queued (not yet decoding) requests
                                       # per model; beyond it submits get 429
    default_deadline_s: float = 0.0    # 0 = no deadline; per-request
                                       # "deadline_s" overrides
    max_gen_length: int = 1024         # request-validation cap on gen_length
    max_steps: int = 4096              # cap on the per-request steps
                                       # override: one request must not be
                                       # able to park the model's single
                                       # decode worker on an absurd step
                                       # budget (deadlines only bound
                                       # QUEUED time)
    stream_retain: int = 256           # finished event streams kept for a
                                       # late GET /v1/stream/{rid}
    max_body_bytes: int = 1 << 20      # POST body cap (413 beyond; chunked
                                       # bodies are rejected outright)
    retry_after_s: float = 1.0         # Retry-After header on 429/503
    profile_dir: str = ""              # non-empty = bracket each decoded
                                       # batch with jax.profiler
                                       # start_trace/stop_trace, dumping
                                       # device profiles here (ops use:
                                       # flip on, reproduce, flip off)
    supervisor: SupervisorConfig = SupervisorConfig()
    degrade: DegradeConfig = DegradeConfig()


@dataclass(frozen=True)
class RouterConfig:
    """Multi-model router (``repro.serving.router``) knobs.

    ``budget_bytes`` caps the summed parameter bytes of RESIDENT engines:
    admitting or rebuilding a model evicts idle least-recently-used
    engines until the batch fits (a busy engine — queued or mid-decode —
    is never evicted; the budget may transiently overshoot if everything
    is busy, and converges as decodes drain).  Evicting an engine drops
    the process's last strong reference to its params, so the Decoder's
    weak runner cache frees the compiled executables too —
    ``decode_cache_info()`` observably shrinks.
    """
    budget_bytes: int = 0              # 0 = unlimited
    max_models: int = 0                # 0 = unlimited registered models


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 32
    seq_len: int = 64
    steps: int = 300
    lr: float = 3e-4
    warmup: int = 20
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    seed: int = 0
    log_every: int = 50
    eval_every: int = 100
    ckpt_dir: str = ""
