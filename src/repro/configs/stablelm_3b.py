"""stablelm-3b — dense decoder, MHA-like (kv=32)
[hf:stabilityai/stablelm-2-1_6b family].

32 layers, d_model=2560, 32 heads (kv=32), d_ff=6912, vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    max_seq_len=32768,
    remat="block",
)
