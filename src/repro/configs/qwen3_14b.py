"""qwen3-14b — dense GQA decoder with per-head q/k RMSNorm [hf:Qwen/Qwen3-8B family].

40 layers, d_model=5120, 40 heads (GQA kv=8), d_ff=17408, vocab=151936, qk_norm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    max_seq_len=32768,
    remat="block",
)
