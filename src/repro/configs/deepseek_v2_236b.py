"""deepseek-v2-236b — MLA + fine-grained MoE [arXiv:2405.04434].

60 layers, d_model=5120, 128 heads, MLA (kv_lora=512, q_lora=1536,
qk_nope=128, qk_rope=64, v_head=128), per-expert d_ff=1536,
2 shared + 160 routed experts top-6, vocab=102400.  First layer dense.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,            # MLA: per-head KV reconstructed from 512-d latent
    head_dim=192,                # qk_nope + qk_rope
    d_ff=12288,                  # the single dense (first_k_dense) layer
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, num_experts_per_tok=6, num_shared_experts=2,
                  moe_d_ff=1536, first_k_dense=1),
    max_seq_len=131072,
    remat="block",
)
