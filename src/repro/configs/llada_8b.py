"""llada-8b — the paper's own model [arXiv:2502.09992].

LLaDA-8B: 32 layers, d_model=4096, 32 heads (MHA), d_ff=12288, vocab=126464,
bidirectional attention, mask-prediction head.  This is the reference LLDM the
FDM experiments in the paper run on.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llada-8b",
    arch_type="dense",
    source="arXiv:2502.09992",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=12288,
    vocab_size=126464,
    max_seq_len=4096,
    remat="block",
)
