"""whisper-medium — encoder-decoder audio transformer backbone [arXiv:2212.04356].

24 decoder layers, d_model=1024, 16 heads (kv=16), d_ff=4096, vocab=51865.
The conv/mel frontend is a STUB: ``input_specs`` supplies precomputed frame
embeddings of shape (B, 1500, 1024); we implement the transformer that consumes
them (encoder self-attn stack + diffusion decoder with cross-attention).
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="encdec",
    source="arXiv:2212.04356",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    rope="sinusoidal",
    norm="layernorm",
    act="gelu",
    encdec=EncDecConfig(encoder_layers=24, encoder_seq=1500, frontend="audio_stub"),
    max_seq_len=4096,
    remat="block",
)
