"""qwen2-vl-72b — VLM language backbone with M-RoPE [arXiv:2409.12191].

80 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=29568, vocab=152064.
Vision encoder is a STUB: input_specs supplies precomputed patch embeddings
(dynamic-resolution token count fixed to 1024 stand-in patches); the language
model consumes them through the shared embedding stream with 3-section M-RoPE
(temporal/height/width) position ids.
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    rope="mrope",
    mrope_sections=(16, 24, 24),   # t,h,w split of head_dim/2 = 64
    rope_theta=1_000_000.0,
    encdec=EncDecConfig(frontend="vision_stub", num_patch_tokens=1024),
    max_seq_len=32768,
    remat="block",
)
