"""Fused selective-scan (Mamba) Pallas kernel — the kernel §Perf D says
the hybrid architectures need.

The jnp formulation materializes decay/drive/state tensors of shape
(B, L, d_inner, N) — N=16 times the activation size — which made
hymba × train_4k the only memory-bound row of the roofline table (28 s of
HBM traffic; chunking took it to 22 s, D1, and no further, D2).  The CUDA
answer is mamba's fused selective-scan kernel; this is the TPU analogue:

* grid (batch, d_inner tiles, time tiles), time innermost;
* the recurrent state h (DI_TILE, N) lives in VMEM scratch across time
  tiles; decay/drive are computed IN REGISTERS from the streamed inputs
  (x, Δ, B, C) and never touch HBM;
* HBM traffic = read x/Δ/B/C once + write y once — independent of N;
* the time loop is sequential (a scan is a scan) but each step is a
  (DI_TILE × N) = 2048-lane VPU elementwise block, which keeps the VPU
  busy; DI tiles and batches are embarrassingly parallel across the grid.

Traffic napkin (hymba train_4k, per device): inputs+outputs ≈ 4·L·d_inner
·4 B ≈ 0.9 GB/layer vs ≈ 12 GB/layer for the chunked jnp scan — ~13×.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DI_TILE = 128
T_TILE = 256


def _sscan_kernel(x_ref, delta_ref, b_ref, c_ref, a_ref, y_ref, h_ref,
                  *, t_tiles: int, seq: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (T_TILE, DI_TILE)
    delta = delta_ref[0].astype(jnp.float32)  # (T_TILE, DI_TILE)
    bsel = b_ref[0].astype(jnp.float32)       # (T_TILE, N)
    csel = c_ref[0].astype(jnp.float32)       # (T_TILE, N)
    a = a_ref[...].astype(jnp.float32)        # (DI_TILE, N) — negative reals

    def step(t, carry):
        h, y = carry
        # decay/drive computed in registers — never materialized over time
        dt_t = delta[t][:, None]                        # (DI, 1)
        decay = jnp.exp(dt_t * a)                       # (DI, N)
        drive = dt_t * bsel[t][None, :] * x[t][:, None]
        h = decay * h + drive
        y = y.at[t].set(jnp.sum(h * csel[t][None, :], axis=1))
        return h, y

    y0 = jnp.zeros_like(x)
    h, y = jax.lax.fori_loop(0, T_TILE, step, (h_ref[...], y0))
    h_ref[...] = h
    # ragged last tile: rows beyond seq hold garbage but are sliced off
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_scan(x: jnp.ndarray, delta: jnp.ndarray, b_sel: jnp.ndarray,
                   c_sel: jnp.ndarray, a_log: jnp.ndarray,
                   interpret: bool = True) -> jnp.ndarray:
    """x/delta (B, L, di), b_sel/c_sel (B, L, N), a_log (di, N) -> y (B, L, di).

    h_t = exp(Δ_t·A) ⊙ h_{t-1} + Δ_t·B_t·x_t ;  y_t = ⟨h_t, C_t⟩
    with A = -exp(a_log) (negative-real diagonal).
    """
    bsz, l, di = x.shape
    n = a_log.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    pad_t = (-l) % T_TILE
    pad_d = (-di) % DI_TILE
    def pad3(z):
        return jnp.pad(z, ((0, 0), (0, pad_t), (0, pad_d))) \
            if pad_d else jnp.pad(z, ((0, 0), (0, pad_t), (0, 0)))

    xp, dp = pad3(x), pad3(delta)
    bp = jnp.pad(b_sel, ((0, 0), (0, pad_t), (0, 0)))
    cp = jnp.pad(c_sel, ((0, 0), (0, pad_t), (0, 0)))
    ap = jnp.pad(a, ((0, pad_d), (0, 0))) if pad_d else a
    lt, dt_ = xp.shape[1], xp.shape[2]
    t_tiles, d_tiles = lt // T_TILE, dt_ // DI_TILE

    kernel = functools.partial(_sscan_kernel, t_tiles=t_tiles, seq=l)
    y = pl.pallas_call(
        kernel,
        grid=(bsz, d_tiles, t_tiles),
        in_specs=[
            pl.BlockSpec((1, T_TILE, DI_TILE), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, T_TILE, DI_TILE), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, T_TILE, n), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, T_TILE, n), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((DI_TILE, n), lambda b, d, t: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, T_TILE, DI_TILE),
                               lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((DI_TILE, n), jnp.float32)],
        interpret=interpret,
    )(xp, dp, bp, cp, ap)
    return y[:, :l, :di]
