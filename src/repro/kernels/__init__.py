"""Pallas TPU kernels for the decode-scoring and attention hot spots.

``confidence``      — fused streaming (argmax / max-prob / margin / entropy)
``flash_attention`` — bidirectional flash attention + sliding-window band
``ops``             — jit'd public wrappers with jnp fallback dispatch
``ref``             — pure-jnp oracles (the allclose ground truth)
"""
from repro.kernels.ops import attention, score_logits_fused, use_pallas

__all__ = ["attention", "score_logits_fused", "use_pallas"]
