"""Bidirectional flash attention Pallas kernel (+ sliding-window variant).

LLDMs attend bidirectionally (every masked position sees every other), so
the kernel has no causal path — the mask structure is either *full* or a
*band* |i−j| < window (the diffusion adaptation of Mixtral's SWA and the
sub-quadratic route for ``long_500k``).

Tiling: grid (batch·heads, q_tiles, k_tiles), k innermost; online-softmax
accumulators (m, l, acc) in VMEM scratch. Block shapes default to
(128, 128) — MXU-native — with the head dim kept whole (≤ 256 for every
assigned arch).  For the banded variant, out-of-window K-tiles are skipped
entirely with ``pl.when`` (compute-free, the structural analogue of
restricting the grid), which turns O(L²) into O(L·W) work.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QTILE = 128
KTILE = 128
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, window: int, k_tiles: int, seq_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # band pruning: tile distance guaranteed out of window -> skip all work
    q_start = qi * QTILE
    k_start = kj * KTILE

    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (QTILE, d)
        k = k_ref[0].astype(jnp.float32)                  # (KTILE, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < seq_k                              # ragged last tile
        if window:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            valid = valid & (jnp.abs(qpos - kpos) < window)
        s = jnp.where(valid, s, NEG)

        m_old, l_old = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        l_ref[...] = l_old * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if window:
        # closest approach of the two tiles decides whether any work exists
        dist = jnp.maximum(q_start - (k_start + KTILE - 1),
                           k_start - (q_start + QTILE - 1))
        pl.when(dist < window)(_compute)
    else:
        _compute()

    @pl.when(kj == k_tiles - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    window: int = 0, interpret: bool = True) -> jnp.ndarray:
    """q/k/v (B, L, H, d) heads pre-expanded -> (B, L, H, d).

    ``window=0`` is full bidirectional attention; ``window=W`` the band.
    """
    b, lq, h, d = q.shape
    lk = k.shape[1]
    scale = d ** -0.5
    # fold (B, H) and pad sequence to tile multiples
    def fold(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, a.shape[1], d)

    qf, kf, vf = fold(q), fold(k), fold(v)
    pq, pk = (-lq) % QTILE, (-lk) % KTILE
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    q_tiles = qf.shape[1] // QTILE
    k_tiles = kf.shape[1] // KTILE

    kernel = functools.partial(_flash_kernel, scale=scale, window=window,
                               k_tiles=k_tiles, seq_k=lk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, q_tiles, k_tiles),
        in_specs=[
            pl.BlockSpec((1, QTILE, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, KTILE, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, KTILE, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, QTILE, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((QTILE,), jnp.float32),      # m
            pltpu.VMEM((QTILE,), jnp.float32),      # l
            pltpu.VMEM((QTILE, d), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :lq].reshape(b, h, lq, d).transpose(0, 2, 1, 3)
    return out
