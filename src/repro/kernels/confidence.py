"""Fused decode-confidence Pallas kernel.

The decode-order hot spot: every sampler step reduces logits (B, L, V) —
V up to 152 k — to four per-position scalars (argmax token, max prob, top-2
margin, negative entropy).  A naive implementation materializes the full
softmax in HBM up to three times (softmax, top_k, entropy); at bf16 32 k × 152 k
logits that is ~28 GB of traffic per extra pass on a problem that is
strictly memory-bound (arithmetic intensity < 10 flops/byte « the 240
flop/byte v5e ridge point).

This kernel streams the vocab axis through VMEM **once**, maintaining
online-softmax accumulators per row:

    m   — running max logit          s  — Σ exp(l − m)
    u   — Σ l·exp(l − m)             (m₂, i₁) — top-2 value / argmax index

from which all four outputs are exact (no approximation):

    max_prob  = exp(m − m − log s)            = 1/s · exp(0)
    margin    = (exp(m−m) − exp(m₂−m)) / s
    neg_ent   = u/s − (m + log s)     since Σ p·log p = E[l] − logZ

Grid: (row_tiles, vocab_tiles) with the vocab axis innermost; accumulators
live in VMEM scratch and the outputs are written by the last vocab tile.
Block shapes are MXU/VPU aligned: (ROWS=8, VTILE=512) float32 ⇒ 16 KiB per
block, comfortably inside the ~16 MiB VMEM budget with double buffering.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS = 8          # rows (positions) per block
VTILE = 512       # vocab lanes per block (128-multiple)
NEG = -3.4e38     # ~f32 lowest


def _confidence_kernel(logits_ref, argmax_ref, maxp_ref, margin_ref,
                       negent_ref, m_ref, s_ref, u_ref, m2_ref, i1_ref,
                       *, vocab: int, vtiles: int):
    vj = pl.program_id(1)

    @pl.when(vj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        u_ref[...] = jnp.zeros_like(u_ref)
        m2_ref[...] = jnp.full_like(m2_ref, NEG)
        i1_ref[...] = jnp.zeros_like(i1_ref)

    tile = logits_ref[...].astype(jnp.float32)            # (ROWS, VTILE)
    # mask lanes beyond the true vocab (ragged last tile)
    lane = vj * VTILE + jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    tile = jnp.where(lane < vocab, tile, NEG)

    # per-tile top-2 + argmax
    t1 = jnp.max(tile, axis=1)                            # (ROWS,)
    ti = jnp.argmax(tile, axis=1).astype(jnp.int32) + vj * VTILE
    masked = jnp.where(tile >= t1[:, None], NEG, tile)    # drop (all) maxima
    t2 = jnp.max(masked, axis=1)
    # duplicate maxima inside one tile: true top-2 equals the max
    dup = jnp.sum((tile >= t1[:, None]).astype(jnp.int32), axis=1) > 1
    t2 = jnp.where(dup, t1, t2)

    m_old, s_old, u_old = m_ref[...], s_ref[...], u_ref[...]
    m2_old, i1_old = m2_ref[...], i1_ref[...]

    m_new = jnp.maximum(m_old, t1)
    # rescale old accumulators to the new max
    alpha = jnp.exp(m_old - m_new)                        # 0 when m_old=NEG
    ex = jnp.exp(tile - m_new[:, None])
    ex = jnp.where(lane < vocab, ex, 0.0)
    s_new = s_old * alpha + jnp.sum(ex, axis=1)
    u_new = u_old * alpha + jnp.sum(tile * ex, axis=1)
    # top-2 merge: candidates {m_old, m2_old, t1, t2} minus the new top-1
    take_new = t1 > m_old
    m2_new = jnp.where(take_new, jnp.maximum(m_old, t2),
                       jnp.maximum(m2_old, t1))
    i1_new = jnp.where(take_new, ti, i1_old)

    m_ref[...], s_ref[...], u_ref[...] = m_new, s_new, u_new
    m2_ref[...], i1_ref[...] = m2_new, i1_new

    @pl.when(vj == vtiles - 1)
    def _finish():
        logz = m_new + jnp.log(s_new)
        inv_s = 1.0 / s_new
        maxp = inv_s                                      # exp(m - m)/s
        p2 = jnp.exp(m2_new - m_new) * inv_s
        argmax_ref[...] = i1_new
        maxp_ref[...] = maxp
        margin_ref[...] = maxp - p2
        negent_ref[...] = u_new * inv_s - logz


@functools.partial(jax.jit, static_argnames=("interpret",))
def confidence_fused(logits: jnp.ndarray, interpret: bool = True
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                jnp.ndarray]:
    """(..., V) -> (argmax, max_prob, margin, neg_entropy), single HBM pass.

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container's validation mode); on TPU pass ``interpret=False``.
    """
    shape = logits.shape
    v = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    flat = logits.reshape(rows, v)
    pad_rows = (-rows) % ROWS
    if pad_rows:
        flat = jnp.pad(flat, ((0, pad_rows), (0, 0)))
    r = flat.shape[0]
    vtiles = -(-v // VTILE)

    kernel = functools.partial(_confidence_kernel, vocab=v, vtiles=vtiles)
    out_shape = [
        jax.ShapeDtypeStruct((r,), jnp.int32),    # argmax
        jax.ShapeDtypeStruct((r,), jnp.float32),  # max_prob
        jax.ShapeDtypeStruct((r,), jnp.float32),  # margin
        jax.ShapeDtypeStruct((r,), jnp.float32),  # neg_entropy
    ]
    row_spec = pl.BlockSpec((ROWS,), lambda i, j: (i,))
    outs = pl.pallas_call(
        kernel,
        grid=(r // ROWS, vtiles),
        in_specs=[pl.BlockSpec((ROWS, VTILE), lambda i, j: (i, j))],
        out_specs=[row_spec] * 4,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((ROWS,), jnp.float32),  # m
            pltpu.VMEM((ROWS,), jnp.float32),  # s
            pltpu.VMEM((ROWS,), jnp.float32),  # u
            pltpu.VMEM((ROWS,), jnp.float32),  # m2
            pltpu.VMEM((ROWS,), jnp.int32),    # i1
        ],
        interpret=interpret,
    )(flat)
    argmax, maxp, margin, negent = outs

    def unflat(a):
        return a[:rows].reshape(shape[:-1])

    return (unflat(argmax), unflat(maxp), unflat(margin), unflat(negent))
