"""Public jit'd wrappers for the Pallas kernels with jnp fallback dispatch.

On this CPU container the kernels run in ``interpret=True`` mode, which is
slow (Python-level emulation) but bit-faithful — so the default execution
path uses the pure-jnp reference and the kernels are exercised by the test
suite + benchmarks.  On a real TPU set ``use_pallas(True)`` (or env
``REPRO_USE_PALLAS=1``) to route the hot paths through the fused kernels.
"""
from __future__ import annotations

import os
import jax
import jax.numpy as jnp

from repro.core.confidence import Scores
from repro.kernels import ref as ref_lib
from repro.kernels.confidence import confidence_fused
from repro.kernels.flash_attention import flash_attention

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
_STATE = {"use_pallas": bool(int(os.environ.get("REPRO_USE_PALLAS", "0")))
          or _ON_TPU}


def use_pallas(flag: bool) -> None:
    _STATE["use_pallas"] = flag


def score_logits_fused(logits: jnp.ndarray) -> Scores:
    """Fused (single HBM pass) version of ``core.confidence.score_logits``."""
    if _STATE["use_pallas"]:
        a, p, m, e = confidence_fused(logits, interpret=not _ON_TPU)
    else:
        a, p, m, e = ref_lib.confidence_ref(logits)
    return Scores(argmax=a, max_prob=p, margin=m, neg_entropy=e)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              window: int = 0) -> jnp.ndarray:
    """Flash bidirectional attention (band-masked when window > 0)."""
    if _STATE["use_pallas"]:
        return flash_attention(q, k, v, window=window,
                               interpret=not _ON_TPU)
    return ref_lib.attention_ref(q, k, v, window=window)
