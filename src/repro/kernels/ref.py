"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def confidence_ref(logits: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(..., V) -> (argmax i32, max_prob, margin, neg_entropy) each (...,).

    The naive reference: full softmax materialized, separate top-2 pass.
    """
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    p = jnp.exp(logp)
    top2_p, top2_i = jax.lax.top_k(p, 2)
    neg_ent = jnp.sum(p * logp, axis=-1)
    return (top2_i[..., 0].astype(jnp.int32), top2_p[..., 0],
            top2_p[..., 0] - top2_p[..., 1], neg_ent)


def selective_scan_ref(x, delta, b_sel, c_sel, a_log) -> jnp.ndarray:
    """Sequential-scan oracle for the fused selective-scan kernel.

    h_t = exp(Δ_t·A) ⊙ h_{t-1} + Δ_t·B_t·x_t ;  y_t = ⟨h_t, C_t⟩.
    """
    a = -jnp.exp(a_log.astype(jnp.float32))             # (di, N)
    xf = x.astype(jnp.float32)
    df = delta.astype(jnp.float32)

    def step(h, t):
        decay = jnp.exp(df[:, t][..., None] * a)        # (B, di, N)
        drive = (df[:, t][..., None] * b_sel[:, t][:, None, :]
                 * xf[:, t][..., None])
        h = decay * h + drive
        y = jnp.sum(h * c_sel[:, t][:, None, :], axis=-1)
        return h, y

    bsz, l, di = x.shape
    h0 = jnp.zeros((bsz, di, a_log.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(l))
    return ys.swapaxes(0, 1).astype(x.dtype)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  window: int = 0) -> jnp.ndarray:
    """Bidirectional (optionally banded) attention reference.

    q (B, Lq, H, d), k/v (B, Lk, H, d) — heads already expanded (no GQA
    grouping at kernel level; the wrapper repeats KV heads).
    """
    b, lq, h, d = q.shape
    lk = k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    if window:
        qi = jnp.arange(lq)[:, None]
        ki = jnp.arange(lk)[None, :]
        band = jnp.abs(qi - ki) < window
        scores = jnp.where(band[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
