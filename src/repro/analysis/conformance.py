"""Jaxpr grain: strategy-contract checking by tracing, never executing.

Every registered strategy must hold four contracts that no unit test can
state once-for-all (they quantify over *future* strategies):

  ANA101  the carry pytree (structure, shapes, dtypes) is a fixed-point
          of ``begin_block``, ``fused_step`` and ``step``, and every
          fused driver (``drive_block``'s while_loop, ``drive_request``'s
          scan, and their KV-cached twins under both cache policies)
          traces with it — a carry that grows or re-dtypes breaks the
          ``lax.while_loop`` carry invariant at runtime, on the first
          request that hits the strategy.
  ANA102  the fused jaxprs contain no callback primitives, except the
          one sanctioned *ordered* streaming ``io_callback`` that
          ``drive_request`` itself plants when given ``emit``.
  ANA103  no constant baked into a fused jaxpr exceeds a byte threshold
          (weights must arrive as traced arguments, or every params
          update recompiles and the executable bloats).
  ANA104  re-tracing ``fused_step`` under ``jax.experimental.enable_x64``
          keeps every canvas/carry leaf out of float64 — a Python-float
          constant that silently promotes doubles the FLOPs the day x64
          is enabled.
  ANA105  the step-telemetry contract: wrapping the strategy in
          ``tracing(...)`` (``core/tracebuffer.py``) must preserve every
          contract above — the TraceBuffer rides the carry, so a
          non-fixed-shape write surfaces as an ANA101 break of the
          wrapped strategy — and with trace **off** the raw drivers'
          jaxprs must contain no ``trace_capacity``-sized array at all:
          telemetry that leaks into the trace=off graph would change
          compiled decode for every request that never asked for it.

Everything runs through ``jax.eval_shape`` / ``jax.make_jaxpr`` on a
tiny synthetic harness (a weightless one-hot "model", B=2, 12-column
canvas, two 4-wide blocks), so a full 10-strategy sweep costs traces,
not decodes, and runs in CI without an accelerator.

``step`` (the host variant) is *allowed* to concretize — strategies like
``extrapolate`` and ``fdm_a`` sync on purpose there — so concretization
errors from ``step`` are tolerated; everything else is a finding.

Entry points: ``check_strategy`` (one strategy -> findings),
``assert_conforms`` (raises ``ConformanceError`` — the conftest guard),
``conformance_findings`` (every registered strategy — the CLI).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding, make_finding
from repro.configs.base import DecodeConfig, ModelConfig

DEFAULT_CONST_BYTES = 1 << 18         # 256 KiB: generous for schedules,
                                      # far below any real weight matrix

_TRACE_TOLERATED = (
    "TracerBoolConversionError", "TracerArrayConversionError",
    "TracerIntegerConversionError", "ConcretizationTypeError",
)


class ConformanceError(AssertionError):
    """A registered strategy violates a fused-decode contract."""


def _tiny_setup(strategy_name: str) -> Tuple[ModelConfig, DecodeConfig]:
    cfg = ModelConfig(name="analysis-tiny", arch_type="dense",
                      num_layers=1, d_model=16, num_heads=2,
                      num_kv_heads=2, d_ff=32, vocab_size=31)
    dcfg = DecodeConfig(gen_length=8, block_size=4, steps=4,
                        strategy=strategy_name, k=2, k1=2)
    return cfg, dcfg


def _toy_model_fn(cfg: ModelConfig) -> Callable:
    v = cfg.vocab_size

    def model_fn(x):
        # weightless but rank-correct: peaked logits, any batch size
        # (FDM calls it with the K-candidate batch folded in)
        return jax.nn.one_hot((x + 1) % v, v, dtype=jnp.float32) * 8.0

    return model_fn


def _spec(tree) -> Tuple:
    """Hashable (treedef, leaf shape/dtype list) signature of a pytree."""
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef, tuple((jnp.shape(l), jnp.result_type(l))
                           for l in leaves))


def _spec_str(spec) -> str:
    treedef, leaves = spec
    shapes = ", ".join(f"{tuple(s)}:{d}" for s, d in leaves)
    return f"{treedef} [{shapes}]"


def _is_jaxpr(obj) -> bool:
    return hasattr(obj, "eqns") or (hasattr(obj, "jaxpr")
                                    and hasattr(obj.jaxpr, "eqns"))


def _iter_eqns(jaxpr):
    """All equations, recursing into control-flow sub-jaxprs."""
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    for eqn in inner.eqns:
        yield eqn
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for sub in vs:
                if _is_jaxpr(sub):
                    yield from _iter_eqns(sub)


def _iter_consts(jaxpr):
    if hasattr(jaxpr, "consts"):
        yield from jaxpr.consts
    for eqn in _iter_eqns(jaxpr):
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for sub in vs:
                if hasattr(sub, "consts"):
                    yield from sub.consts


def _callbacks(jaxpr) -> List:
    return [e for e in _iter_eqns(jaxpr) if "callback" in e.primitive.name]


def _tolerated(err: Exception) -> bool:
    return type(err).__name__ in _TRACE_TOLERATED


def _toy_cached_fns(cfg: ModelConfig) -> Tuple[Callable, Callable]:
    """Weightless stand-ins for the KV-cached model surface: a per-column
    f32 "cache" captured from the canvas, and a windowed forward that
    reads it back (candidate-folded batches included) — enough for the
    cached drivers to trace with real data dependence on the state."""
    v = cfg.vocab_size

    def refresh_fn(canvas):
        return jnp.asarray(canvas % v, jnp.float32)

    def cached_fn(w, win_lo, state):
        bias = jax.lax.dynamic_slice_in_dim(state, win_lo, w.shape[1],
                                            axis=1)
        reps = w.shape[0] // state.shape[0]
        if reps > 1:
            bias = jnp.tile(bias, (reps, 1))
        return jax.nn.one_hot((w + 1) % v, v, dtype=jnp.float32) * 8.0 \
            + bias[..., None] * 1e-3

    return cached_fn, refresh_fn


def check_strategy(strategy, *, batch: int = 2, prompt_len: int = 4,
                   const_bytes: int = DEFAULT_CONST_BYTES,
                   path: Optional[str] = None) -> List[Finding]:
    """Trace one strategy through the fused drivers — plain AND KV-cached
    (both cache policies) — and return findings."""
    import dataclasses

    from repro.core.loop import (drive_block, drive_cached_block,
                                 drive_request, drive_request_cached)
    from repro.core.strategies import as_strategy

    strat = as_strategy(strategy)
    name = strat.name or type(strat).__name__
    where = path or f"strategy:{name}"
    cfg, dcfg = _tiny_setup(name if isinstance(strategy, str) else "fdm")
    model_fn = _toy_model_fn(cfg)
    out: List[Finding] = []

    def finding(rule, msg):
        out.append(make_finding(rule, where, 0, f"[{name}] {msg}"))

    length = prompt_len + dcfg.gen_length
    x0 = jnp.where(jnp.arange(length)[None, :] < prompt_len, 2,
                   cfg.mask_token_id).astype(jnp.int32)
    x0 = jnp.broadcast_to(x0, (batch, length))
    key = jax.random.PRNGKey(0)
    in_block = (jnp.arange(length) >= prompt_len) & (
        jnp.arange(length) < prompt_len + dcfg.block_size)
    active = in_block[None, :] & (x0 == cfg.mask_token_id)
    n = jnp.asarray(1, jnp.int32)
    sched = jnp.full((dcfg.block_size,), 1, jnp.int32)
    steps0 = jnp.asarray(0, jnp.int32)
    fwd0 = jnp.asarray(0.0, jnp.float32)

    try:
        carry0 = strat.init_carry_shaped(cfg, dcfg, batch, length)
    except Exception as e:
        finding("ANA101", f"init_carry_shaped failed: {e}")
        return out
    carry_spec = _spec(carry0)

    # begin_block must return the same carry signature
    try:
        bb = jax.eval_shape(strat.begin_block, carry0, x0, in_block)
        if _spec(bb) != carry_spec:
            finding("ANA101",
                    "begin_block changes the carry signature: "
                    f"{_spec_str(carry_spec)} -> {_spec_str(_spec(bb))}")
    except Exception as e:
        finding("ANA101", f"begin_block does not trace: {e!r}")

    # fused_step / step: carry and canvas fixed-points (static args —
    # model_fn, configs — are closed over; eval_shape abstracts the rest)
    def step_sig(step_fn, label, tolerate_sync):
        def wrapped(k, c, x, a):
            return step_fn(k, c, x, a, model_fn, cfg, dcfg, n)

        try:
            new_x, new_c, _ = jax.eval_shape(wrapped, key, carry0, x0,
                                             active)
        except Exception as e:
            if tolerate_sync and _tolerated(e):
                return                   # host-only step: sanctioned sync
            finding("ANA101", f"{label} does not trace: {e!r}")
            return
        if _spec(new_x) != _spec(x0):
            finding("ANA101", f"{label} changes the canvas signature: "
                    f"{_spec_str(_spec(x0))} -> {_spec_str(_spec(new_x))}")
        if _spec(new_c) != carry_spec:
            finding("ANA101", f"{label} is not a carry fixed-point: "
                    f"{_spec_str(carry_spec)} -> "
                    f"{_spec_str(_spec(new_c))}")

    step_sig(strat.fused_step, "fused_step", tolerate_sync=False)
    step_sig(strat.step, "step", tolerate_sync=True)
    if out:
        return out          # drivers would only re-report the same break

    # both fused drivers must trace with the carry riding them, and their
    # jaxprs must be free of callbacks / giant consts
    def block_fn(x, k, s, f, c):
        return drive_block(strat, model_fn, cfg, dcfg, sched, x, k,
                           in_block, s, f, c)

    block_los = jnp.asarray([prompt_len, prompt_len + dcfg.block_size],
                            jnp.int32)
    schedules = jnp.broadcast_to(sched, (2, sched.shape[0]))

    def request_fn(x, k, s, f, c):
        return drive_request(strat, model_fn, cfg, dcfg, x, k, block_los,
                             schedules, s, f, c)

    def request_emit_fn(x, k, s, f, c):
        return drive_request(strat, model_fn, cfg, dcfg, x, k, block_los,
                             schedules, s, f, c,
                             emit=lambda blk, lo, hi, canvas: None)

    def check_jaxpr(label, fn, args, emit_ok):
        try:
            jaxpr = jax.make_jaxpr(fn)(*args)
        except Exception as e:
            finding("ANA101", f"{label} does not trace with this "
                    f"strategy's carry: {e!r}")
            return
        for eqn in _callbacks(jaxpr):
            prim = eqn.primitive.name
            if (emit_ok and prim == "io_callback"
                    and eqn.params.get("ordered")):
                continue               # the sanctioned streaming callback
            finding("ANA102", f"{label} jaxpr contains {prim} "
                    "(only the ordered streaming io_callback is "
                    "sanctioned in fused decode)")
        for const in _iter_consts(jaxpr):
            nbytes = getattr(const, "nbytes", 0)
            if nbytes and nbytes > const_bytes:
                finding("ANA103", f"{label} jaxpr bakes a "
                        f"{jnp.shape(const)} constant ({nbytes} B > "
                        f"{const_bytes} B) — pass weights as traced "
                        "arguments, not closure captures")

    plain_args = (x0, key, steps0, fwd0, carry0)
    check_jaxpr("drive_block", block_fn, plain_args, False)
    check_jaxpr("drive_request", request_fn, plain_args, False)
    check_jaxpr("drive_request[emit]", request_emit_fn, plain_args, True)

    # the cached fused drivers hold the same contracts per policy: the
    # carry AND the fixed-shape cache state ride the trace as arguments
    # (a baked cache would be an ANA103 finding), and the only callback
    # is still the sanctioned ordered streaming one
    cached_fn, refresh_fn = _toy_cached_fns(cfg)
    state0 = refresh_fn(x0)
    lo0 = jnp.asarray(prompt_len, jnp.int32)
    for policy in ("prefix", "dual"):
        dc = dataclasses.replace(dcfg, cache_policy=policy)

        def cblock_fn(x, k, lo, s, f, c, st, _dc=dc):
            return drive_cached_block(strat, cached_fn, cfg, _dc, x, k,
                                      lo, sched, s, f, c, st)

        def crequest_fn(x, k, s, f, c, _dc=dc):
            return drive_request_cached(
                strat, cached_fn, refresh_fn, cfg, _dc, x, k, block_los,
                schedules, s, f, c,
                emit=lambda blk, lo, hi, canvas: None)

        check_jaxpr(f"drive_cached_block[{policy}]", cblock_fn,
                    (x0, key, lo0, steps0, fwd0, carry0, state0), False)
        check_jaxpr(f"drive_request_cached[{policy},emit]", crequest_fn,
                    plain_args, True)

    # x64 probe: same 32-bit inputs, x64 enabled — promotion to float64
    # means a float constant somewhere isn't weakly typed
    try:
        def x64_probe(k, c, x, a):
            return strat.fused_step(k, c, x, a, model_fn, cfg, dcfg, n)

        with jax.experimental.enable_x64():
            new_x, new_c, _ = jax.eval_shape(x64_probe, key, carry0, x0,
                                             active)
            # inspect INSIDE the context: result_type canonicalizes f64
            # back to f32 once x64 is off again, hiding the promotion
            bad = [(jnp.shape(l), str(jnp.result_type(l)))
                   for l in jax.tree.leaves((new_x, new_c))
                   if jnp.result_type(l) == jnp.float64]
        if bad:
            finding("ANA104", "fused_step promotes to float64 under "
                    f"enable_x64 (leaves {bad}) — use explicit 32-bit "
                    "dtypes or weak Python scalars")
    except Exception as e:
        if not _tolerated(e):
            finding("ANA104", f"x64 probe failed to trace: {e!r}")

    return out


def check_trace_telemetry(strategy_name: str, *,
                          const_bytes: int = DEFAULT_CONST_BYTES
                          ) -> List[Finding]:
    """ANA105, two directions per registered strategy:

    * trace **on**: ``tracing(strategy)`` must hold every fused-decode
      contract itself — its carry carries the TraceBuffer, so the
      ANA101 fixed-point check *is* the proof that telemetry writes are
      fixed-shape, and ANA102/103/104 prove the wrapper adds no
      callbacks, baked constants, or f64 promotion.
    * trace **off**: the raw drivers' jaxprs must be entirely free of
      ``trace_capacity(dcfg)``-sized arrays — the buffer must be
      unreachable from the fused roots unless the wrapper was applied.
    """
    from repro.core.loop import drive_block, drive_request
    from repro.core.strategies import as_strategy
    from repro.core.tracebuffer import trace_capacity, tracing

    strat = as_strategy(strategy_name)
    where = f"strategy:{strategy_name}"
    out: List[Finding] = []

    wrapped = tracing(strat)
    for f in check_strategy(wrapped, const_bytes=const_bytes, path=where):
        out.append(make_finding(
            "ANA105", where, 0,
            f"tracing({strategy_name}) breaks {f.rule}: {f.message}"))

    cfg, dcfg = _tiny_setup(strategy_name)
    cap = trace_capacity(dcfg)
    model_fn = _toy_model_fn(cfg)
    batch, prompt_len = 2, 4
    length = prompt_len + dcfg.gen_length
    x0 = jnp.where(jnp.arange(length)[None, :] < prompt_len, 2,
                   cfg.mask_token_id).astype(jnp.int32)
    x0 = jnp.broadcast_to(x0, (batch, length))
    key = jax.random.PRNGKey(0)
    in_block = (jnp.arange(length) >= prompt_len) & (
        jnp.arange(length) < prompt_len + dcfg.block_size)
    sched = jnp.full((dcfg.block_size,), 1, jnp.int32)
    block_los = jnp.asarray([prompt_len, prompt_len + dcfg.block_size],
                            jnp.int32)
    schedules = jnp.broadcast_to(sched, (2, sched.shape[0]))
    steps0 = jnp.asarray(0, jnp.int32)
    fwd0 = jnp.asarray(0.0, jnp.float32)
    try:
        carry0 = strat.init_carry_shaped(cfg, dcfg, batch, length)
    except Exception:
        return out          # the base sweep already reports ANA101

    def scan(label, fn, args):
        try:
            jaxpr = jax.make_jaxpr(fn)(*args)
        except Exception:
            return          # ditto: tracing failures are ANA101's job
        hits = set()
        for eqn in _iter_eqns(jaxpr):
            for v in list(eqn.invars) + list(eqn.outvars):
                shape = tuple(getattr(getattr(v, "aval", None),
                                      "shape", ()) or ())
                if cap in shape:
                    hits.add(shape)
        if hits:
            out.append(make_finding(
                "ANA105", where, 0,
                f"[{strategy_name}] {label} (trace=off) jaxpr contains "
                f"trace_capacity({cap})-sized arrays {sorted(hits)} — "
                "the TraceBuffer must be unreachable unless "
                "dcfg.trace wrapped the strategy"))

    plain_args = (x0, key, steps0, fwd0, carry0)
    scan("drive_block",
         lambda x, k, s, f, c: drive_block(strat, model_fn, cfg, dcfg,
                                           sched, x, k, in_block, s, f,
                                           c),
         plain_args)
    scan("drive_request",
         lambda x, k, s, f, c: drive_request(strat, model_fn, cfg, dcfg,
                                             x, k, block_los, schedules,
                                             s, f, c),
         plain_args)
    return out


def assert_conforms(strategy) -> None:
    """Raise ``ConformanceError`` listing every violated contract."""
    problems = check_strategy(strategy)
    if problems:
        lines = "\n".join(f"  {f.rule}: {f.message}" for f in problems)
        raise ConformanceError(
            f"strategy fails fused-decode conformance:\n{lines}")


def conformance_findings(names: Optional[Sequence[str]] = None,
                         const_bytes: int = DEFAULT_CONST_BYTES
                         ) -> List[Finding]:
    """Check every registered strategy (the CLI's jaxpr grain): the base
    fused-decode contracts plus the ANA105 telemetry contract."""
    from repro.core.strategies import available_strategies
    out: List[Finding] = []
    for name in names if names is not None else available_strategies():
        out.extend(check_strategy(name, const_bytes=const_bytes))
        out.extend(check_trace_telemetry(name, const_bytes=const_bytes))
    return out
