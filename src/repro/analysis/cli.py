"""CLI for the static-analysis pass.

    python -m repro.analysis src            # the CI gate
    python tools/repro_lint.py src          # same, from a checkout

Exit status 0 = zero unbaselined, unsuppressed findings (warnings
included — severity describes blast radius, the gate is absolute).
Honored suppressions are printed WITH their rationales so intent
survives into CI logs; ``--format github`` emits workflow annotations
that land on the PR diff.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List, Tuple

from repro.analysis import astpass, concpass, suppressions
from repro.analysis.findings import (Finding, RULES, format_text, render)

DEFAULT_BASELINE = os.path.join("tools", "repro_lint_baseline.txt")
DEFAULT_PATHS = ["src", "tools", "benchmarks", "examples"]
GRAINS = ("ast", "jaxpr", "conc")
_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache"}


def collect_files(paths: List[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            out.extend(os.path.join(root, f) for f in sorted(files)
                       if f.endswith(".py"))
    return out


def run_file_grains(files: List[str], grains=("ast", "conc")) -> Tuple[
        List[Finding], Dict[str, Dict[int, suppressions.Suppression]]]:
    """Run the per-file grains (AST and/or concurrency) over ``files``.

    Suppression comments are scanned regardless of grain selection so a
    filtered run still honors (and validates) every rationale."""
    findings: List[Finding] = []
    sups: Dict[str, Dict[int, suppressions.Suppression]] = {}
    for path in files:
        try:
            with open(path, encoding="utf-8") as fh:
                source = fh.read()
        except OSError as e:
            print(f"repro-lint: cannot read {path}: {e}", file=sys.stderr)
            continue
        file_sups, sup_problems = suppressions.scan_suppressions(path,
                                                                 source)
        sups[path] = file_sups
        findings.extend(sup_problems)
        if "ast" in grains:
            findings.extend(astpass.analyze_source(path, source))
        if "conc" in grains:
            findings.extend(concpass.analyze_source(path, source))
    return findings, sups


def run_ast_grain(files: List[str]):
    """Back-compat alias: AST grain only (pre-concurrency callers)."""
    return run_file_grains(files, grains=("ast",))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="AST + jaxpr + concurrency static analysis for the "
                    "fused-decode and serving contracts (see DESIGN.md).")
    ap.add_argument("paths", nargs="*", default=DEFAULT_PATHS,
                    help="files/directories to scan "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--format", choices=("text", "github"),
                    default="text", dest="fmt")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings as the baseline")
    ap.add_argument("--grain", action="append", choices=GRAINS,
                    default=None, metavar="{ast,jaxpr,conc}",
                    help="run only the named grain(s); repeatable "
                         "(default: all three)")
    ap.add_argument("--only-rules", default=None, metavar="ANA…,ANA…",
                    help="keep only findings for these rule ids "
                         "(comma list; suppression hygiene ANA000 is "
                         "always kept)")
    ap.add_argument("--skip-jaxpr", action="store_true",
                    help="legacy: drop the jaxpr grain (same as "
                         "--grain ast --grain conc)")
    ap.add_argument("--strategies", default=None,
                    help="comma list for the jaxpr grain (default: every "
                         "registered strategy)")
    ap.add_argument("--const-bytes", type=int,
                    default=None,
                    help="ANA103 baked-constant threshold in bytes")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, (severity, summary) in sorted(RULES.items()):
            print(f"{rule}  {severity:7s}  {summary}")
        return 0

    grains = set(args.grain) if args.grain else set(GRAINS)
    if args.skip_jaxpr:
        grains.discard("jaxpr")

    files = collect_files(args.paths or DEFAULT_PATHS)
    findings, sups = run_file_grains(files, grains)

    if "jaxpr" in grains:
        from repro.analysis import conformance
        names = (args.strategies.split(",") if args.strategies else None)
        kw = {}
        if args.const_bytes is not None:
            kw["const_bytes"] = args.const_bytes
        findings.extend(conformance.conformance_findings(names, **kw))

    if args.only_rules:
        keep = {r.strip() for r in args.only_rules.split(",") if r.strip()}
        keep.add("ANA000")
        findings = [f for f in findings if f.rule in keep]

    active, suppressed = suppressions.apply_suppressions(findings, sups)
    baseline = suppressions.load_baseline(args.baseline)
    active, baselined = suppressions.apply_baseline(active, baseline)

    if args.write_baseline:
        n = suppressions.write_baseline(args.baseline, active)
        print(f"repro-lint: wrote {n} finding(s) to {args.baseline}")
        return 0

    for f in sorted(suppressed):
        print(f"suppressed: {format_text(f)}  [rationale: {f.suppressed}]")
    if baselined:
        print(f"repro-lint: {len(baselined)} baselined finding(s) "
              f"skipped ({args.baseline})")
    for line in render(active, args.fmt):
        print(line)
    checked = f"{len(files)} file(s)" + (
        " + strategy conformance" if "jaxpr" in grains else "")
    if active:
        print(f"repro-lint: {len(active)} finding(s) in {checked}",
              file=sys.stderr)
        return 1
    print(f"repro-lint: clean ({checked}, "
          f"{len(suppressed)} suppressed)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
