"""Concurrency grain: asyncio/thread contracts over the serving stack.

The serving layer (scheduler, router, server, supervisor) is mixed
asyncio/thread code: one event loop owns queue mutation and event
streams, block-grain decode resumptions run on executor threads, and a
handful of entry points (`shutdown_nowait`, `ServerThread.stop`) are
deliberately callable from foreign threads.  Every shipped race so far
(the PR 6 close()-during-inflight-decode race, the `_inflight` rebind)
lived exactly on those boundaries, so this grain turns the threading
contract in ``scheduler.py``'s docstring into machine checks.

The pass reuses the AST grain's ``ModuleModel`` (module-local call
graph) and layers a **loop-affinity inference** on top: it classifies
each function of a class as *loop-context* or *foreign-thread context*
— foreign means the body of a closure handed to ``run_in_executor`` /
``asyncio.to_thread``, a ``threading.Thread`` target, a method marked by
the thread-entry idiom (it calls ``call_soon_threadsafe`` /
``run_coroutine_threadsafe`` to re-dispatch onto the loop), or anything
module-locally reachable from those — and then checks how the two sides
share ``self`` attributes:

  ANA201  cross-thread state: (a) loop-context code REBINDS a mutable
          container attribute (``self._inflight = set()``) that
          foreign-thread code also touches — a foreign reader can hold
          the stale object across the swap; mutate in place
          (``.clear()``/``.update()``) or guard with a lock;
          (b) the symmetric foreign-side rebind; (c) a foreign-thread
          ``self.x += 1`` on state the loop side also uses (augmented
          assignment is a non-atomic read-modify-write across threads).
          Reads in a thread-entry method count as foreign even after
          its re-dispatch guard: the guard only applies once
          ``self._loop`` is set, and the contract is cheaper to keep
          than the flow analysis to prove it.
  ANA202  await-spanning read-modify-write: in one ``async def``, a
          shared attribute is read, the coroutine suspends (``await`` /
          ``async for`` / ``async with``), and the attribute is written
          afterwards — the written value can be stale because any other
          task ran in the gap (the exact shape of the PR 6 race).
          Only attributes with a second writer elsewhere in the class
          count as shared; accesses inside a held ``with self.<lock>``
          block are exempt (the lock serializes the RMW — ANA203 owns
          lock correctness).  Augmented assignment and keyed stores
          (``self.d[k] = v``, ``self.c[k] += 1``) are exempt: they
          re-read the container at the write site with no suspension
          in between — only a full rebind can publish a stale value.
  ANA203  lock discipline: (a) an ``asyncio.Lock`` attribute touched
          from a foreign-thread context (asyncio locks are loop-affine
          — a foreign thread needs ``threading.Lock``); (b) a
          ``threading.Lock`` entered with ``async with`` (wrong
          protocol) or held across an ``await`` (stalls every thread
          waiting on it for the duration of the suspension, and invites
          lock-order deadlocks); (c) an attribute written both under a
          held lock and outside any lock in the same class — either the
          lock is needed everywhere or nowhere.
  ANA204  task lifecycle: (a) ``create_task``/``ensure_future`` result
          dropped on the floor — the task is garbage-collectable
          mid-flight and its exception is swallowed; keep the handle
          and await/collect it; (b) ``asyncio.wait_for`` directly on a
          ``run_in_executor`` future without ``asyncio.shield`` — an
          executor future cannot be cancelled mid-run, so an
          un-shielded timeout detaches the worker AND loses its
          result/exception; shield it and decide explicitly (the
          scheduler's watchdog idiom).
  ANA205  event-protocol state machine: every stream emission site is
          checked against the declarative lifecycle spec
          ``EVENT_PROTOCOL`` (queued -> block* -> reset? -> exactly one
          terminal of done/cancelled/expired/error/shutdown).  An
          emission is a call to an ``emit``-suffixed function carrying a
          (statically resolvable) dict payload with a ``"type"`` key.
          Checks: the type is in the spec; terminal types carry a
          literal ``"final": True``; non-terminal types don't; a
          payload the checker cannot resolve is itself a finding (a
          hole in the proof, not a free pass); and —
          the exactly-one-terminal proof — every raw ``<stream>.emit()``
          call lives inside the single *guarded emitter* (a method that
          checks ``.finished`` and returns before emitting), so no
          emission path can double-terminate a stream.

Known approximations, on purpose: the model is module-local (an engine
method driven from another module's executor thread is that module's
contract, see ``ServingEngine.summary``); mutating method calls
(``.pop``/``.append``/``.clear``) count as reads, not writes — they are
the sanctioned in-place idiom; and statement order stands in for
control flow.  Intentional violations take an inline
``# repro-lint: ignore[RULE] -- rationale`` like every other grain.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.astpass import ModuleModel, dotted_name, own_nodes
from repro.analysis.findings import Finding, make_finding

#: Declarative stream lifecycle (ANA205).  A request's event stream must
#: match  queued -> block* -> reset? -> <one terminal>.  ``tools/
#: fault_smoke.py`` asserts this dynamically; the checker proves the
#: final-flag discipline and the single-guarded-emitter choke point
#: statically over every emission site.
EVENT_PROTOCOL = {
    "nonterminal": frozenset({"block", "reset"}),
    "terminal": frozenset({"done", "cancelled", "expired", "error",
                           "shutdown"}),
}

_MUTABLE_CTORS = {"set", "dict", "list", "deque", "OrderedDict",
                  "defaultdict", "Counter"}
_THREADSAFE_MARKERS = {"call_soon_threadsafe", "run_coroutine_threadsafe"}
_THREADING_LOCKS = {"Lock", "RLock", "Condition", "Semaphore",
                    "BoundedSemaphore"}


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.X`` -> "X"; None otherwise."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _flat_targets(target: ast.AST) -> Iterator[ast.AST]:
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _flat_targets(elt)
    else:
        yield target


def _end_line(node: ast.AST) -> int:
    return getattr(node, "end_lineno", None) or node.lineno


class ConcModel:
    """Loop-affinity model over one ``ModuleModel``: which functions run
    on foreign threads, which attributes are locks / mutable containers,
    and every ``self.X`` read/write site per function."""

    def __init__(self, mod: ModuleModel):
        self.mod = mod
        # (cls, attr) -> "asyncio" | "threading"
        self.lock_attrs: Dict[Tuple[str, str], str] = {}
        # (cls, attr) initialised to a mutable container in __init__
        self.container_attrs: Set[Tuple[str, str]] = set()
        self._lock_imports = self._import_origins()
        self._collect_inits()
        self.foreign = self.mod._reach(self._executor_contexts()
                                       | self._thread_entries())

    # -- construction ------------------------------------------------------

    def _import_origins(self) -> Dict[str, str]:
        """Bare lock-class names -> owning module ("asyncio"/"threading")."""
        out: Dict[str, str] = {}
        for node in ast.walk(self.mod.tree):
            if isinstance(node, ast.ImportFrom) and node.module in (
                    "asyncio", "threading"):
                for alias in node.names:
                    if alias.name in _THREADING_LOCKS | {"Event"}:
                        out[alias.asname or alias.name] = node.module
        return out

    def _lock_kind(self, value: ast.AST) -> Optional[str]:
        if not isinstance(value, ast.Call):
            return None
        name = dotted_name(value.func)
        if not name:
            return None
        parts = name.split(".")
        if parts[-1] not in _THREADING_LOCKS:
            return None
        if len(parts) > 1 and parts[0] in ("asyncio", "threading"):
            return parts[0]
        return self._lock_imports.get(parts[0])

    def _is_container_init(self, value: ast.AST) -> bool:
        if isinstance(value, (ast.Dict, ast.List, ast.Set, ast.ListComp,
                              ast.SetComp, ast.DictComp)):
            return True
        if isinstance(value, ast.Call):
            name = dotted_name(value.func)
            return bool(name) and name.split(".")[-1] in _MUTABLE_CTORS
        return False

    def _collect_inits(self) -> None:
        for qual, info in self.mod.functions.items():
            if info.cls is None or qual.split(".")[-1] != "__init__":
                continue
            for node in own_nodes(info.node):
                if isinstance(node, ast.Assign):
                    targets = [t for tgt in node.targets
                               for t in _flat_targets(tgt)]
                    value = node.value
                elif isinstance(node, ast.AnnAssign) and node.value:
                    targets = [node.target]
                    value = node.value
                else:
                    continue
                for tgt in targets:
                    attr = _self_attr(tgt)
                    if attr is None:
                        continue
                    kind = self._lock_kind(value)
                    if kind:
                        self.lock_attrs[(info.cls, attr)] = kind
                    elif self._is_container_init(value):
                        self.container_attrs.add((info.cls, attr))

    def _resolve_callable(self, arg: ast.AST, qual: str,
                          cls: Optional[str]) -> Optional[str]:
        if isinstance(arg, ast.Name):
            return self.mod.resolve(arg.id, qual)
        attr = _self_attr(arg)
        if attr and cls:
            return self.mod._method(cls, attr)
        return None

    def _executor_contexts(self) -> Set[str]:
        """Functions whose bodies run on a non-loop thread: executor /
        to_thread callables and ``threading.Thread`` targets."""
        out: Set[str] = set()
        for qual, info in self.mod.functions.items():
            for node in own_nodes(info.node):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func) or ""
                last = name.split(".")[-1]
                cand: Optional[ast.AST] = None
                if last == "run_in_executor" and len(node.args) >= 2:
                    cand = node.args[1]
                elif last == "to_thread" and node.args:
                    cand = node.args[0]
                elif last == "Thread":
                    for kw in node.keywords:
                        if kw.arg == "target":
                            cand = kw.value
                if cand is not None:
                    tgt = self._resolve_callable(cand, qual, info.cls)
                    if tgt:
                        out.add(tgt)
        return out

    def _thread_entries(self) -> Set[str]:
        """Methods written to be CALLED from foreign threads — marked by
        the re-dispatch idiom (``call_soon_threadsafe`` /
        ``run_coroutine_threadsafe`` in their own body)."""
        out: Set[str] = set()
        for qual, info in self.mod.functions.items():
            for node in own_nodes(info.node):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in _THREADSAFE_MARKERS):
                    out.add(qual)
                    break
        return out

    # -- per-function access sites -----------------------------------------

    def writes(self, qual: str) -> List[Tuple[str, int, str]]:
        """``self.X`` write sites: (attr, line, kind) with kind one of
        ``rebind`` (plain assign to the attribute itself), ``aug``
        (augmented assign), ``store`` (subscript store into it)."""
        info = self.mod.functions[qual]
        out: List[Tuple[str, int, str]] = []
        for node in own_nodes(info.node):
            if isinstance(node, ast.Assign):
                targets = [t for tgt in node.targets
                           for t in _flat_targets(tgt)]
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = list(_flat_targets(node.target))
            else:
                continue
            kind = "aug" if isinstance(node, ast.AugAssign) else "rebind"
            for tgt in targets:
                attr = _self_attr(tgt)
                if attr is not None:
                    out.append((attr, tgt.lineno, kind))
                elif isinstance(tgt, ast.Subscript):
                    attr = _self_attr(tgt.value)
                    if attr is not None:
                        out.append((attr, tgt.lineno, "store"))
        return out

    def reads(self, qual: str) -> List[Tuple[str, int]]:
        info = self.mod.functions[qual]
        out = []
        for node in own_nodes(info.node):
            attr = _self_attr(node)
            if attr is not None and isinstance(node.ctx, ast.Load):
                out.append((attr, node.lineno))
        return out

    def touched(self, qual: str) -> Set[str]:
        return ({a for a, _ in self.reads(qual)}
                | {a for a, _, _ in self.writes(qual)})

    def locked_spans(self, qual: str) -> List[Tuple[int, int, str, bool]]:
        """``with self.<lock>`` regions: (lo, hi, kind, is_async_with).
        Attributes are recognised as locks when typed in ``__init__`` or,
        failing that, when the name contains "lock"."""
        info = self.mod.functions[qual]
        out = []
        for node in own_nodes(info.node):
            if not isinstance(node, (ast.With, ast.AsyncWith)):
                continue
            for item in node.items:
                attr = _self_attr(item.context_expr)
                if attr is None:
                    continue
                kind = self.lock_attrs.get((info.cls, attr)) if info.cls \
                    else None
                if kind is None and "lock" not in attr.lower():
                    continue
                out.append((node.lineno, _end_line(node), kind or "unknown",
                            isinstance(node, ast.AsyncWith)))
        return out

    def suspensions(self, qual: str) -> List[int]:
        """Lines where the coroutine may yield to the loop."""
        info = self.mod.functions[qual]
        return sorted(node.lineno for node in own_nodes(info.node)
                      if isinstance(node, (ast.Await, ast.AsyncFor,
                                           ast.AsyncWith)))

    def class_methods(self, cls: str) -> List[str]:
        return [q for q, i in self.mod.functions.items() if i.cls == cls]


# -- ANA201: cross-thread access to loop-affine state ----------------------

def rule_loop_affinity(mod: ModuleModel) -> List[Finding]:
    cm = ConcModel(mod)
    if not cm.foreign:
        return []
    out: List[Finding] = []
    classes = {i.cls for i in mod.functions.values() if i.cls}
    for cls in sorted(classes):
        methods = cm.class_methods(cls)
        foreign_ms = [q for q in methods if q in cm.foreign]
        loop_ms = [q for q in methods if q not in cm.foreign
                   and not q.endswith(".__init__")]
        if not foreign_ms:
            continue
        foreign_touched = {a for q in foreign_ms for a in cm.touched(q)}
        loop_touched = {a for q in loop_ms for a in cm.touched(q)}
        # (a) loop-side rebind of a shared mutable container
        for qual in loop_ms:
            for attr, line, kind in cm.writes(qual):
                if (kind == "rebind" and attr in foreign_touched
                        and (cls, attr) in cm.container_attrs):
                    out.append(make_finding(
                        "ANA201", mod.path, line,
                        f"self.{attr} is rebound in {qual} while a "
                        f"foreign-thread context "
                        f"({', '.join(sorted(foreign_ms))}) also touches "
                        "it — a foreign reader can hold the stale object "
                        "across the swap; mutate in place "
                        "(.clear()/.update()) or guard with a lock"))
        for qual in foreign_ms:
            if qual.endswith(".__init__"):
                continue
            for attr, line, kind in cm.writes(qual):
                # (b) foreign-side rebind of a shared mutable container
                if (kind == "rebind" and attr in loop_touched
                        and (cls, attr) in cm.container_attrs):
                    out.append(make_finding(
                        "ANA201", mod.path, line,
                        f"self.{attr} is rebound from the foreign-thread "
                        f"context {qual} while event-loop code also "
                        "touches it — publish through the loop "
                        "(call_soon_threadsafe) or mutate in place"))
                # (c) foreign-side augmented assign on shared state
                elif kind == "aug" and attr in loop_touched:
                    out.append(make_finding(
                        "ANA201", mod.path, line,
                        f"self.{attr} += ... in the foreign-thread "
                        f"context {qual} races event-loop accesses — "
                        "augmented assignment is a non-atomic "
                        "read-modify-write across threads; hold a "
                        "threading.Lock or hand off to the loop"))
    return out


# -- ANA202: await-spanning read-modify-write ------------------------------

def rule_await_rmw(mod: ModuleModel) -> List[Finding]:
    cm = ConcModel(mod)
    out: List[Finding] = []
    # writers per (cls, attr), excluding __init__ — an attribute with a
    # single writer has no interleaving writer to go stale against
    writers: Dict[Tuple[str, str], Set[str]] = {}
    for qual, info in mod.functions.items():
        if info.cls is None or qual.endswith(".__init__"):
            continue
        for attr, _, _ in cm.writes(qual):
            writers.setdefault((info.cls, attr), set()).add(qual)
    for qual, info in mod.functions.items():
        if not info.is_async or info.cls is None:
            continue
        waits = cm.suspensions(qual)
        if not waits:
            continue
        spans = cm.locked_spans(qual)

        def guarded(line: int) -> bool:
            return any(lo <= line <= hi for lo, hi, _, _ in spans)

        reads: Dict[str, int] = {}
        for attr, line in cm.reads(qual):
            if not guarded(line) and (attr not in reads
                                      or line < reads[attr]):
                reads[attr] = line
        for attr, line, kind in cm.writes(qual):
            # only full rebinds can publish a stale value: augmented
            # assignment and keyed stores (self.d[k] = v, self.c[k] += 1)
            # re-read the container at the write site
            if kind != "rebind" or guarded(line):
                continue
            if len(writers.get((info.cls, attr), ())) < 2:
                continue
            first_read = reads.get(attr)
            if first_read is None or first_read >= line:
                continue
            if any(first_read < w < line for w in waits):
                out.append(make_finding(
                    "ANA202", mod.path, line,
                    f"self.{attr} is read at line {first_read}, the "
                    f"coroutine suspends, and self.{attr} is written "
                    f"here ({qual}) — another task can interleave in "
                    "the gap, making this write stale; re-read after "
                    "the await, claim-then-act before it, or hold a "
                    "lock across the whole read-modify-write"))
    return out


# -- ANA203: lock discipline -----------------------------------------------

def rule_lock_discipline(mod: ModuleModel) -> List[Finding]:
    cm = ConcModel(mod)
    out: List[Finding] = []
    # (a) asyncio locks touched from foreign-thread contexts
    for qual in sorted(cm.foreign & set(mod.functions)):
        info = mod.functions[qual]
        if info.cls is None:
            continue
        for attr, line in cm.reads(qual):
            if cm.lock_attrs.get((info.cls, attr)) == "asyncio":
                out.append(make_finding(
                    "ANA203", mod.path, line,
                    f"asyncio.Lock self.{attr} touched from the "
                    f"foreign-thread context {qual} — asyncio locks are "
                    "loop-affine (not thread-safe); use threading.Lock "
                    "for cross-thread state"))
    for qual, info in mod.functions.items():
        waits = cm.suspensions(qual)
        for lo, hi, kind, is_async_with in cm.locked_spans(qual):
            # (b) threading locks misused inside coroutines
            if kind == "threading" and is_async_with:
                out.append(make_finding(
                    "ANA203", mod.path, lo,
                    "`async with` on a threading.Lock — threading locks "
                    "have no async protocol; use asyncio.Lock on the "
                    "loop side"))
            elif kind == "threading" and info.is_async and any(
                    lo < w <= hi for w in waits):
                out.append(make_finding(
                    "ANA203", mod.path, lo,
                    "threading.Lock held across an await — every thread "
                    "contending on it blocks for the whole suspension; "
                    "release before awaiting or use asyncio.Lock"))
    # (c) attributes written both under a lock and outside any lock
    classes = {i.cls for i in mod.functions.values() if i.cls}
    for cls in sorted(classes):
        locked_writes: Dict[str, int] = {}
        bare_writes: Dict[str, List[Tuple[int, str]]] = {}
        for qual in cm.class_methods(cls):
            if qual.endswith(".__init__"):
                continue
            spans = cm.locked_spans(qual)
            for attr, line, _ in cm.writes(qual):
                if any(lo <= line <= hi for lo, hi, _, _ in spans):
                    locked_writes.setdefault(attr, line)
                else:
                    bare_writes.setdefault(attr, []).append((line, qual))
        for attr, guarded_line in sorted(locked_writes.items()):
            for line, qual in bare_writes.get(attr, ()):
                out.append(make_finding(
                    "ANA203", mod.path, line,
                    f"self.{attr} is written under a lock at line "
                    f"{guarded_line} but without one here ({qual}) — "
                    "mixed discipline; either every write holds the "
                    "lock or none needs to"))
    return out


# -- ANA204: task lifecycle ------------------------------------------------

def _is_executor_future(node: ast.AST,
                        executor_locals: Set[str]) -> bool:
    if isinstance(node, ast.Call):
        name = dotted_name(node.func) or ""
        return name.split(".")[-1] == "run_in_executor"
    return isinstance(node, ast.Name) and node.id in executor_locals


def rule_task_lifecycle(mod: ModuleModel) -> List[Finding]:
    out: List[Finding] = []
    # (a) fire-and-forget create_task: the returned handle is the ONLY
    # strong reference keeping the task alive, and the only way its
    # exception ever surfaces
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Expr)
                and isinstance(node.value, ast.Call)):
            continue
        name = dotted_name(node.value.func) or ""
        if name.split(".")[-1] in ("create_task", "ensure_future"):
            out.append(make_finding(
                "ANA204", mod.path, node.lineno,
                f"{name}(…) result dropped — the task can be "
                "garbage-collected mid-flight and its exception is "
                "silently swallowed; keep the handle and await or "
                "collect it"))
    # (b) wait_for on a bare executor future: cancellation cannot stop
    # the worker, it only detaches the future and loses its outcome
    for info in mod.functions.values():
        # pass 1: locals bound to executor futures (own_nodes has no
        # source-order guarantee, so collect before checking)
        executor_locals: Set[str] = set()
        for node in own_nodes(info.node):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                vname = dotted_name(node.value.func) or ""
                if vname.split(".")[-1] == "run_in_executor":
                    for tgt in _flat_targets(node.targets[0]):
                        if isinstance(tgt, ast.Name):
                            executor_locals.add(tgt.id)
        for node in own_nodes(info.node):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func) or ""
            if name.split(".")[-1] != "wait_for" or not node.args:
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Call):
                inner = dotted_name(arg.func) or ""
                if inner.split(".")[-1] == "shield":
                    continue
            if _is_executor_future(arg, executor_locals):
                out.append(make_finding(
                    "ANA204", mod.path, node.lineno,
                    "wait_for on a bare run_in_executor future — the "
                    "timeout cancels the future but the worker thread "
                    "keeps running with its result and exception "
                    "dropped; wrap in asyncio.shield and handle the "
                    "timeout explicitly (the scheduler watchdog idiom)"))
    return out


# -- ANA205: event-protocol state machine ----------------------------------

def _dict_literal(node: ast.AST, qual: str,
                  mod: ModuleModel) -> Optional[ast.Dict]:
    """Resolve an emission payload to a dict literal: either directly,
    or through a module-local helper whose body is ``return {…}``."""
    if isinstance(node, ast.Dict):
        return node
    if isinstance(node, ast.Call):
        info = mod.functions.get(qual)
        tgt = None
        if isinstance(node.func, ast.Name):
            tgt = mod.resolve(node.func.id, qual)
        elif info is not None:
            attr = _self_attr(node.func)
            if attr and info.cls:
                tgt = mod._method(info.cls, attr)
        if tgt:
            for n in own_nodes(mod.functions[tgt].node):
                if isinstance(n, ast.Return) and isinstance(n.value,
                                                            ast.Dict):
                    return n.value
    return None


def _dict_str(d: ast.Dict, key: str):
    for k, v in zip(d.keys, d.values):
        if isinstance(k, ast.Constant) and k.value == key:
            return v
    return None


def _guarded_emitters(mod: ModuleModel) -> Set[str]:
    """Functions that check ``.finished`` (and return) before calling
    ``.emit`` — the sanctioned choke points for stream emission."""
    out: Set[str] = set()
    for qual, info in mod.functions.items():
        guard_line = None
        emit_line = None
        for node in own_nodes(info.node):
            if isinstance(node, ast.If) and any(
                    isinstance(n, ast.Attribute) and n.attr == "finished"
                    for n in ast.walk(node.test)) and any(
                    isinstance(n, ast.Return) for n in node.body):
                guard_line = node.lineno if guard_line is None \
                    else min(guard_line, node.lineno)
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "emit"):
                emit_line = node.lineno if emit_line is None \
                    else max(emit_line, node.lineno)
        if guard_line is not None and emit_line is not None \
                and guard_line < emit_line:
            out.add(qual)
    return out


def _speaks_protocol(mod: ModuleModel) -> bool:
    """The module constructs stream-lifecycle events: some dict literal
    carries a ``"final"`` key or a protocol ``"type"`` value."""
    types = EVENT_PROTOCOL["terminal"] | EVENT_PROTOCOL["nonterminal"]
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Dict):
            continue
        if _dict_str(node, "final") is not None:
            return True
        t = _dict_str(node, "type")
        if isinstance(t, ast.Constant) and t.value in types:
            return True
    return False


def rule_event_protocol(mod: ModuleModel) -> List[Finding]:
    if not _speaks_protocol(mod):
        return []
    out: List[Finding] = []
    emitters = _guarded_emitters(mod)
    terminal = EVENT_PROTOCOL["terminal"]
    nonterminal = EVENT_PROTOCOL["nonterminal"]
    for qual, info in mod.functions.items():
        for node in own_nodes(info.node):
            if not isinstance(node, ast.Call):
                continue
            # raw stream.emit() outside the guarded emitter breaks the
            # exactly-one-terminal proof: nothing checks `finished`
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "emit"
                    and qual not in emitters):
                out.append(make_finding(
                    "ANA205", mod.path, node.lineno,
                    f".emit() called directly in {qual}, bypassing the "
                    "guarded emitter — nothing checks `finished` first, "
                    "so a stream can receive a second terminal event; "
                    "route every emission through the single guarded "
                    "emitter"))
                continue
            name = dotted_name(node.func) or ""
            if not name.split(".")[-1].endswith("emit") or \
                    name.split(".")[-1] == "emit":
                continue
            resolved = [(a, _dict_literal(a, qual, mod))
                        for a in node.args]
            payloads = [(a, d) for a, d in resolved
                        if d is not None and _dict_str(d, "type")
                        is not None]
            if not payloads:
                # a site the checker cannot see through is a hole in
                # the exactly-one-terminal proof, not a free pass
                out.append(make_finding(
                    "ANA205", mod.path, node.lineno,
                    f"emission payload in {qual} cannot be resolved to "
                    "a dict literal with a \"type\" key — pass the "
                    "event literal (or a module-local helper returning "
                    "one) so the lifecycle spec stays statically "
                    "checkable"))
                continue
            for arg, d in payloads:
                tnode = _dict_str(d, "type")
                fnode = _dict_str(d, "final")
                is_final = (isinstance(fnode, ast.Constant)
                            and fnode.value is True)
                if not isinstance(tnode, ast.Constant) or not isinstance(
                        tnode.value, str):
                    out.append(make_finding(
                        "ANA205", mod.path, node.lineno,
                        "event type is not a string literal — the "
                        "lifecycle spec cannot be checked statically"))
                    continue
                etype = tnode.value
                if etype not in terminal | nonterminal:
                    out.append(make_finding(
                        "ANA205", mod.path, node.lineno,
                        f"unknown event type {etype!r} — the stream "
                        f"lifecycle spec allows "
                        f"{sorted(nonterminal)} then exactly one of "
                        f"{sorted(terminal)}"))
                elif etype in terminal and not is_final:
                    out.append(make_finding(
                        "ANA205", mod.path, node.lineno,
                        f"terminal event {etype!r} without a literal "
                        "`\"final\": True` — readers would never "
                        "release the stream"))
                elif etype in nonterminal and fnode is not None:
                    out.append(make_finding(
                        "ANA205", mod.path, node.lineno,
                        f"non-terminal event {etype!r} carries a "
                        "`final` key — it would terminate the stream "
                        "early"))
    return out


CONC_RULES = (rule_loop_affinity, rule_await_rmw, rule_lock_discipline,
              rule_task_lifecycle, rule_event_protocol)


def analyze_source(path: str, source: str) -> List[Finding]:
    """Run every concurrency rule over one file (no suppressions)."""
    try:
        mod = ModuleModel(path, source)
    except SyntaxError as e:
        return [make_finding("ANA000", path, e.lineno or 0,
                             f"file does not parse: {e.msg}")]
    out: List[Finding] = []
    for rule in CONC_RULES:
        out.extend(rule(mod))
    return out
