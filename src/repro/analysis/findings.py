"""Finding model and output formats for the static-analysis pass.

A ``Finding`` is one rule violation at one location.  Findings are plain
frozen dataclasses so rules can build them cheaply, tests can compare
them, and the CLI can sort/dedupe them.  Two output formats:

* ``text``   — ``path:line: RULE severity: message`` (editors hotlink it)
* ``github`` — GitHub Actions workflow annotations (``::error file=…``)
  so the gating CI job paints violations onto the PR diff.

The *baseline key* deliberately omits the line number: a committed
baseline must survive unrelated edits shifting code up and down, so a
finding is identified by what and where-ish (file, rule, message), not
by its exact line.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, List, Optional

SEVERITIES = ("error", "warning")

# Rule catalog: id -> (severity, one-line summary).  DESIGN.md "Static
# contracts" documents each in full; ``--list-rules`` prints this table.
RULES = {
    "ANA000": ("error", "suppression comment without a rationale"),
    "ANA001": ("error", "host sync reachable from fused decode code"),
    "ANA002": ("error", "jit identity churn (recompile per call)"),
    "ANA003": ("error", "PRNG key consumed twice without split"),
    "ANA004": ("error", "cache decorator strongly references params"),
    "ANA005": ("error", "blocking call inside async def"),
    "ANA006": ("warning", "io_callback without ordered=True"),
    "ANA101": ("error", "strategy carry is not a driver fixed-point"),
    "ANA102": ("error", "unsanctioned callback in fused jaxpr"),
    "ANA103": ("warning", "large constant baked into fused jaxpr"),
    "ANA104": ("error", "float64 promotion under enable_x64"),
    "ANA105": ("error", "step-telemetry contract broken (TraceBuffer "
                        "not fixed-shape, or reachable when trace=off)"),
    "ANA201": ("error", "cross-thread access to loop-affine state"),
    "ANA202": ("error", "await-spanning read-modify-write"),
    "ANA203": ("error", "lock discipline violation"),
    "ANA204": ("error", "task/future lifecycle hazard"),
    "ANA205": ("error", "event emission violates the stream protocol"),
}


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation.  ``path`` is as given to the analyzer (repo-
    relative in CI); jaxpr-grain findings use the pseudo-path
    ``strategy:<name>`` and line 0."""

    path: str
    line: int
    rule: str
    message: str
    severity: str = "error"
    suppressed: Optional[str] = None   # rationale text when suppressed

    @property
    def baseline_key(self) -> str:
        return f"{self.path}::{self.rule}::{self.message}"

    def suppress(self, rationale: str) -> "Finding":
        return replace(self, suppressed=rationale)


def make_finding(rule: str, path: str, line: int, message: str) -> Finding:
    severity = RULES.get(rule, ("error",))[0]
    return Finding(path=path, line=line, rule=rule, message=message,
                   severity=severity)


def format_text(f: Finding) -> str:
    return f"{f.path}:{f.line}: {f.rule} {f.severity}: {f.message}"


def format_github(f: Finding) -> str:
    """One GitHub Actions annotation command per finding."""
    level = "error" if f.severity == "error" else "warning"
    msg = (f.message.replace("%", "%25").replace("\r", "%0D")
           .replace("\n", "%0A"))
    if f.line > 0:
        loc = f"file={f.path},line={f.line},title={f.rule}"
    else:
        loc = f"title={f.rule} {f.path}"
    return f"::{level} {loc}::{msg}"


def render(findings: Iterable[Finding], fmt: str) -> List[str]:
    fn = format_github if fmt == "github" else format_text
    return [fn(f) for f in sorted(findings)]
