"""Static analysis for the repo's fused-decode and serving contracts.

Two grains (DESIGN.md "Static contracts"):

* **AST** (``astpass``) — source-level rules over ``src/``: host syncs
  reachable from fused roots, jit identity churn, PRNG key reuse,
  strong params refs in caches, blocking calls in async defs, unordered
  ``io_callback``.
* **jaxpr** (``conformance``) — trace-level contracts for every
  registered strategy: the carry is a driver fixed-point, fused jaxprs
  carry no unsanctioned callbacks, no baked weights, no f64 promotion.

CLI: ``python -m repro.analysis src`` (or ``tools/repro_lint.py``) —
the gating CI job.  ``assert_conforms`` is the programmatic guard
``tests/conftest.py`` applies to every strategy a test registers.
"""
from repro.analysis.astpass import AST_RULES, analyze_source
from repro.analysis.conformance import (ConformanceError, assert_conforms,
                                        check_strategy,
                                        conformance_findings)
from repro.analysis.findings import Finding, RULES

__all__ = [
    "AST_RULES", "ConformanceError", "Finding", "RULES",
    "analyze_source", "assert_conforms", "check_strategy",
    "conformance_findings",
]
