"""Static analysis for the repo's fused-decode and serving contracts.

Three grains (DESIGN.md "Static contracts"):

* **AST** (``astpass``) — source-level rules over ``src/``: host syncs
  reachable from fused roots, jit identity churn, PRNG key reuse,
  strong params refs in caches, blocking calls in async defs, unordered
  ``io_callback``.
* **jaxpr** (``conformance``) — trace-level contracts for every
  registered strategy: the carry is a driver fixed-point, fused jaxprs
  carry no unsanctioned callbacks, no baked weights, no f64 promotion.
* **concurrency** (``concpass``) — asyncio/thread contracts over the
  serving stack: loop-affinity of shared attributes, await-spanning
  read-modify-writes, lock discipline, task lifecycle, and the
  event-stream protocol (exactly one terminal event per request).

CLI: ``python -m repro.analysis`` (or ``tools/repro_lint.py``) — the
gating CI job; ``--grain``/``--only-rules`` filter.  ``assert_conforms``
is the programmatic guard ``tests/conftest.py`` applies to every
strategy a test registers.
"""
from repro.analysis.astpass import AST_RULES, analyze_source
from repro.analysis.concpass import (CONC_RULES, EVENT_PROTOCOL,
                                     analyze_source as
                                     analyze_concurrency)
from repro.analysis.conformance import (ConformanceError, assert_conforms,
                                        check_strategy,
                                        conformance_findings)
from repro.analysis.findings import Finding, RULES

__all__ = [
    "AST_RULES", "CONC_RULES", "ConformanceError", "EVENT_PROTOCOL",
    "Finding", "RULES", "analyze_concurrency", "analyze_source",
    "assert_conforms", "check_strategy", "conformance_findings",
]
