"""AST grain: source-level rules over ``src/`` (no imports, no tracing).

The pass parses each file once, builds a *module-local* call graph
(enough for the contracts this repo cares about — fused roots and their
helpers always live in the same module), and runs the rule set:

  ANA001  host-sync calls (``.item()``, ``device_get``, ``np.asarray``,
          ``float()/int()/bool()`` on non-literals, ``block_until_ready``)
          in any function *reachable from fused decode roots*.  Roots:
          functions named ``fused_step``/``drive_block``/``drive_request``,
          ``@jax.jit``-decorated defs, and functions passed into
          ``lax.while_loop/scan/cond/fori_loop/switch``.
  ANA002  jit identity churn: ``jax.jit(lambda …)``, jit calls inside
          Python loops, and nested ``@jax.jit`` defs returned by a
          factory — every call builds a fresh callable, so XLA's jit
          cache misses and silently recompiles per call.  Exemption: a
          factory whose *name* is handed to a ``….get(…)`` call is the
          runner-cache builder idiom (``core/decoder.py``) — the cache
          guarantees the factory runs once per key.
  ANA003  PRNG key reuse: the same key name consumed by two
          ``jax.random.*`` sampling calls with no intervening rebind
          (or consumed inside a loop that never rebinds it) — correlated
          samples, the classic silent-degradation bug.
  ANA004  ``lru_cache``/``cache`` decorators over params-like arguments
          (``params``/``model_fn``/…): the cache owns a strong reference
          and the weights can never be garbage collected.  The repo's
          contract is the weak, identity-keyed ``RunnerCache``.
  ANA005  blocking calls (``time.sleep``, sync file/socket/subprocess
          IO) directly inside ``async def`` bodies — they stall the
          whole event loop, not one request.  Nested sync ``def``s are
          exempt (the scheduler runs those via ``run_in_executor``).
  ANA006  ``io_callback(…)`` without a literal ``ordered=True``:
          unordered callbacks may observe blocks out of commit order,
          breaking the SSE streaming contract.

Reachability is an over-approximation (all call sites, no data flow);
anything intentional gets an inline suppression with a rationale
(``suppressions.py``).  Each rule is a function over ``ModuleModel`` so
adding one is: write the function, append to ``AST_RULES``, document it
in ``findings.RULES`` and DESIGN.md, add a seeded-bug + clean test.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.findings import Finding, make_finding

_FUSED_ROOT_NAMES = {"fused_step", "drive_block", "drive_request"}
_LAX_CONTROL_FLOW = {"while_loop", "scan", "cond", "fori_loop", "switch",
                     "associative_scan"}
_HOST_SYNC_METHODS = {"item", "block_until_ready", "tolist"}
_NP_MODULES = {"np", "numpy", "onp"}
_NP_SYNC_FNS = {"asarray", "array"}
_RANDOM_EXEMPT = {"split", "fold_in", "PRNGKey", "key", "key_data",
                  "wrap_key_data", "clone", "key_impl"}
_PARAMS_LIKE = {"params", "model", "model_fn", "weights", "apply_fn",
                "state", "fn"}
_BLOCKING_CALLS = {       # dotted-name suffixes that block the event loop
    "time.sleep", "os.system", "subprocess.run", "subprocess.call",
    "subprocess.check_output", "subprocess.check_call",
    "subprocess.Popen", "urllib.request.urlopen", "urlopen",
    "socket.create_connection", "requests.get", "requests.post",
    "requests.request",
}


def dotted_name(node: ast.AST) -> Optional[str]:
    """`a.b.c` -> "a.b.c"; None for anything not a pure name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_name(name: Optional[str]) -> bool:
    return name in ("jit", "jax.jit")


def _is_jit_decorator(dec: ast.AST) -> bool:
    """@jax.jit / @jit / @functools.partial(jax.jit, …) / @jax.jit(…)."""
    if _is_jit_name(dotted_name(dec)):
        return True
    if isinstance(dec, ast.Call):
        fn = dotted_name(dec.func)
        if _is_jit_name(fn):
            return True
        if fn in ("functools.partial", "partial"):
            return any(_is_jit_name(dotted_name(a)) for a in dec.args)
    return False


@dataclass
class FuncInfo:
    qualname: str                       # "Class.method" / "outer.inner"
    node: ast.AST                       # FunctionDef | AsyncFunctionDef
    cls: Optional[str]                  # innermost enclosing class name
    parent: Optional[str]               # enclosing function qualname
    is_async: bool
    jit_decorated: bool
    calls: Set[str] = field(default_factory=set)   # resolved qualnames


def own_nodes(fn_node: ast.AST) -> Iterator[ast.AST]:
    """Walk a function's own body, NOT descending into nested def/class.

    Lambdas stay in — they execute in the enclosing trace context."""
    stack = list(ast.iter_child_nodes(fn_node))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


class ModuleModel:
    """One parsed file: function table, local call graph, fused roots."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.functions: Dict[str, FuncInfo] = {}
        self._collect(self.tree, scope=(), cls=None)
        self._resolve_calls()
        self.roots = self._find_roots()
        self.reachable = self._reach(self.roots)

    # -- construction ------------------------------------------------------

    def _collect(self, node: ast.AST, scope: Tuple[str, ...],
                 cls: Optional[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = ".".join(scope + (child.name,))
                self.functions[qual] = FuncInfo(
                    qualname=qual, node=child, cls=cls,
                    parent=".".join(scope) or None,
                    is_async=isinstance(child, ast.AsyncFunctionDef),
                    jit_decorated=any(_is_jit_decorator(d)
                                      for d in child.decorator_list))
                self._collect(child, scope + (child.name,), cls)
            elif isinstance(child, ast.ClassDef):
                self._collect(child, scope + (child.name,), child.name)
            else:
                self._collect(child, scope, cls)

    def resolve(self, name: str, from_qual: str) -> Optional[str]:
        """Resolve a bare name from inside ``from_qual``: own nested defs
        first, then enclosing scopes outward, then module level."""
        parts = from_qual.split(".")
        for depth in range(len(parts), -1, -1):
            cand = ".".join(parts[:depth] + [name])
            if cand in self.functions:
                return cand
        return None

    def _resolve_calls(self) -> None:
        for qual, info in self.functions.items():
            for node in own_nodes(info.node):
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                if isinstance(fn, ast.Name):
                    tgt = self.resolve(fn.id, qual)
                    if tgt:
                        info.calls.add(tgt)
                elif (isinstance(fn, ast.Attribute)
                      and isinstance(fn.value, ast.Name)
                      and fn.value.id == "self" and info.cls):
                    # class-local only: Strategy.fused_step -> self.step
                    # must not leak across subclasses in other files
                    tgt = self._method(info.cls, fn.attr)
                    if tgt:
                        info.calls.add(tgt)

    def _method(self, cls: str, name: str) -> Optional[str]:
        for qual, info in self.functions.items():
            if info.cls == cls and qual.split(".")[-1] == name:
                return qual
        return None

    def _find_roots(self) -> Set[str]:
        roots = {q for q, i in self.functions.items()
                 if i.node.name in _FUSED_ROOT_NAMES or i.jit_decorated}
        # functions handed to lax control flow become traced loop bodies
        for qual, info in self.functions.items():
            for node in own_nodes(info.node):
                if not isinstance(node, ast.Call):
                    continue
                fn = dotted_name(node.func)
                if fn and fn.split(".")[-1] in _LAX_CONTROL_FLOW:
                    for arg in node.args:
                        if isinstance(arg, ast.Name):
                            tgt = self.resolve(arg.id, qual)
                            if tgt:
                                roots.add(tgt)
        return roots

    def _reach(self, roots: Set[str]) -> Set[str]:
        seen: Set[str] = set()
        frontier = list(roots)
        while frontier:
            qual = frontier.pop()
            if qual in seen or qual not in self.functions:
                continue
            seen.add(qual)
            frontier.extend(self.functions[qual].calls)
        return seen


# -- ANA001: host syncs reachable from fused roots -------------------------

def _host_sync_reason(node: ast.Call) -> Optional[str]:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        if fn.attr in _HOST_SYNC_METHODS:
            return f".{fn.attr}() forces a device->host sync"
        name = dotted_name(fn)
        if name and name.split(".")[-1] == "device_get":
            return "device_get() blocks on device results"
        if (isinstance(fn.value, ast.Name) and fn.value.id in _NP_MODULES
                and fn.attr in _NP_SYNC_FNS):
            return (f"{fn.value.id}.{fn.attr}() materializes the array "
                    "on host")
    elif isinstance(fn, ast.Name):
        if fn.id == "device_get":
            return "device_get() blocks on device results"
        if fn.id in ("float", "int", "bool") and node.args and not all(
                _statically_concrete(a) for a in node.args):
            return (f"{fn.id}() on a traced value concretizes it "
                    "(host sync / TracerBoolConversionError)")
    return None


def _statically_concrete(arg: ast.AST) -> bool:
    """True when float()/int()/bool() of ``arg`` cannot sync: literals,
    and shape/len() arithmetic (static under trace)."""
    if isinstance(arg, ast.Constant):
        return True
    for n in ast.walk(arg):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim",
                                                       "size"):
            return True
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id == "len"):
            return True
    return False


def rule_host_sync(mod: ModuleModel) -> List[Finding]:
    out = []
    for qual in sorted(mod.reachable):
        info = mod.functions[qual]
        for node in own_nodes(info.node):
            if isinstance(node, ast.Call):
                reason = _host_sync_reason(node)
                if reason:
                    out.append(make_finding(
                        "ANA001", mod.path, node.lineno,
                        f"{reason} — reachable from fused decode root "
                        f"(via {qual})"))
    return out


# -- ANA002: jit identity churn --------------------------------------------

def _loop_jit_calls(body_owner: ast.AST) -> Iterator[ast.AST]:
    """jit expressions / @jit defs syntactically inside for/while loops."""
    for node in ast.walk(body_owner):
        if not isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
            continue
        for inner in ast.walk(node):
            if inner is node:
                continue
            if (isinstance(inner, ast.Call)
                    and _is_jit_name(dotted_name(inner.func))):
                yield inner
            elif (isinstance(inner, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))
                  and any(_is_jit_decorator(d)
                          for d in inner.decorator_list)):
                yield inner


def rule_jit_churn(mod: ModuleModel) -> List[Finding]:
    out = []
    # (a) jit of a lambda: fresh identity per call site execution
    for node in ast.walk(mod.tree):
        if (isinstance(node, ast.Call)
                and _is_jit_name(dotted_name(node.func))
                and node.args and isinstance(node.args[0], ast.Lambda)):
            out.append(make_finding(
                "ANA002", mod.path, node.lineno,
                "jax.jit(lambda …): a new lambda object per evaluation "
                "defeats the jit cache — hoist to a module-level def"))
    # (b) jit inside a Python loop
    for node in _loop_jit_calls(mod.tree):
        out.append(make_finding(
            "ANA002", mod.path, node.lineno,
            "jit inside a Python loop re-wraps every iteration — "
            "jit once outside the loop"))
    # (c) nested @jit def returned by a factory (new jit per factory
    # call), unless the factory feeds a `.get(…)` runner-cache call
    cached_builders = set()
    for node in ast.walk(mod.tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "get"):
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    cached_builders.add(arg.id)
    for qual, info in mod.functions.items():
        if not info.jit_decorated or info.parent is None:
            continue
        parent = mod.functions.get(info.parent)
        if parent is None or parent.node.name in cached_builders:
            continue
        returned = any(
            isinstance(n, ast.Return) and isinstance(n.value, ast.Name)
            and n.value.id == info.node.name
            for n in own_nodes(parent.node))
        if returned:
            out.append(make_finding(
                "ANA002", mod.path, info.node.lineno,
                f"@jax.jit def {info.node.name} is rebuilt and returned "
                f"on every {parent.node.name}() call — each carries a "
                "fresh jit cache (silent recompiles); route through the "
                "runner cache or jit at module level"))
    return out


# -- ANA003: PRNG key reuse ------------------------------------------------

def _assigned_names(node: ast.AST) -> Iterator[str]:
    targets: List[ast.AST] = []
    if isinstance(node, ast.Assign):
        targets = list(node.targets)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign, ast.NamedExpr)):
        targets = [node.target]
    elif isinstance(node, (ast.For, ast.AsyncFor)):
        targets = [node.target]
    elif isinstance(node, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in node.items if i.optional_vars]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                yield n.id


def _key_consumption(node: ast.AST) -> Optional[str]:
    """Name of the PRNG key consumed by a jax.random sampler call."""
    if not (isinstance(node, ast.Call) and node.args
            and isinstance(node.args[0], ast.Name)):
        return None
    fn = dotted_name(node.func)
    if not fn:
        return None
    parts = fn.split(".")
    if (len(parts) >= 2 and parts[-2] == "random"
            and parts[-1] not in _RANDOM_EXEMPT):
        return node.args[0].id
    return None


class _KeyFlow:
    """Ordered, branch-aware scan for double key consumption."""

    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self._emitted: Set[int] = set()

    def run(self, fn_node: ast.AST) -> None:
        self._stmts(list(ast.iter_child_nodes(fn_node)), {})

    def _stmts(self, stmts, live: Dict[str, int]) -> None:
        for node in stmts:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(node, ast.If):
                a, b = dict(live), dict(live)
                self._expr(node.test, live)
                self._stmts(node.body, a)
                self._stmts(node.orelse, b)
                live.clear()
                live.update({**a, **b})
                continue
            if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
                # two passes: the second sees the first's consumptions, so
                # a loop that samples without rebinding its key trips here
                if isinstance(node, ast.While):
                    self._expr(node.test, live)
                for n in _assigned_names(node):
                    live.pop(n, None)
                self._stmts(node.body, live)
                self._stmts(node.body, live)
                self._stmts(node.orelse, live)
                continue
            if isinstance(node, ast.Try):
                self._stmts(node.body, live)
                for h in node.handlers:
                    self._stmts(h.body, dict(live))
                self._stmts(node.orelse, live)
                self._stmts(node.finalbody, live)
                continue
            # plain statement: expressions first, then its (re)bindings
            self._expr(node, live)
            for n in _assigned_names(node):
                live.pop(n, None)
            if isinstance(node, (ast.With, ast.AsyncWith)):
                self._stmts(node.body, live)

    def _expr(self, node: ast.AST, live: Dict[str, int]) -> None:
        for n in ast.walk(node):
            name = _key_consumption(n)
            if name is None:
                continue
            if name in live and n.lineno not in self._emitted:
                self._emitted.add(n.lineno)
                self.findings.append(make_finding(
                    "ANA003", self.path, n.lineno,
                    f"PRNG key {name!r} already consumed at line "
                    f"{live[name]} and reused without jax.random.split — "
                    "correlated samples"))
            live[name] = n.lineno


def rule_key_reuse(mod: ModuleModel) -> List[Finding]:
    out: List[Finding] = []
    for qual in sorted(mod.functions):
        flow = _KeyFlow(mod.path)
        flow.run(mod.functions[qual].node)
        out.extend(flow.findings)
    return out


# -- ANA004: strong params refs in cache decorators ------------------------

def rule_strong_cache(mod: ModuleModel) -> List[Finding]:
    out = []
    for info in mod.functions.values():
        for dec in info.node.decorator_list:
            name = dotted_name(dec.func if isinstance(dec, ast.Call)
                               else dec)
            if name not in ("functools.lru_cache", "lru_cache",
                            "functools.cache", "cache"):
                continue
            args = info.node.args
            names = [a.arg for a in
                     args.posonlyargs + args.args + args.kwonlyargs]
            hot = sorted(set(names) & _PARAMS_LIKE)
            if hot:
                out.append(make_finding(
                    "ANA004", mod.path, info.node.lineno,
                    f"{name} over {info.node.name}({', '.join(hot)}) "
                    "pins model weights forever — use the weak, "
                    "identity-keyed RunnerCache (core/decoder.py)"))
    return out


# -- ANA005: blocking calls in async defs ----------------------------------

def rule_async_blocking(mod: ModuleModel) -> List[Finding]:
    out = []
    for info in mod.functions.values():
        if not info.is_async:
            continue
        for node in own_nodes(info.node):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            blocked = None
            if isinstance(node.func, ast.Name) and node.func.id == "open":
                blocked = "open()"
            elif name and (name in _BLOCKING_CALLS or any(
                    name.endswith("." + b) for b in _BLOCKING_CALLS)):
                blocked = name + "()"
            if blocked:
                out.append(make_finding(
                    "ANA005", mod.path, node.lineno,
                    f"{blocked} inside `async def {info.node.name}` "
                    "stalls the whole event loop — await an async "
                    "equivalent or push it through run_in_executor"))
    return out


# -- ANA006: unordered io_callback -----------------------------------------

def rule_unordered_callback(mod: ModuleModel) -> List[Finding]:
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if not name or name.split(".")[-1] != "io_callback":
            continue
        ordered = any(
            kw.arg == "ordered" and isinstance(kw.value, ast.Constant)
            and kw.value.value is True for kw in node.keywords)
        if not ordered:
            out.append(make_finding(
                "ANA006", mod.path, node.lineno,
                "io_callback without ordered=True may observe blocks out "
                "of commit order — the streaming contract requires the "
                "ordered variant"))
    return out


AST_RULES = (rule_host_sync, rule_jit_churn, rule_key_reuse,
             rule_strong_cache, rule_async_blocking,
             rule_unordered_callback)


def analyze_source(path: str, source: str) -> List[Finding]:
    """Run every AST rule over one file's source (no suppressions)."""
    try:
        mod = ModuleModel(path, source)
    except SyntaxError as e:
        return [make_finding("ANA000", path, e.lineno or 0,
                             f"file does not parse: {e.msg}")]
    out: List[Finding] = []
    for rule in AST_RULES:
        out.extend(rule(mod))
    return out
