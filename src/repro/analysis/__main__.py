"""``python -m repro.analysis`` — see ``repro.analysis.cli``."""
import os
import sys

from repro.analysis.cli import main

try:
    rc = main()
except BrokenPipeError:    # stdout piped into a closed head/grep
    os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
    rc = 0
sys.exit(rc)
