"""Inline suppressions and the committed baseline file.

Two escape hatches, with different intents:

* **Inline suppression** — a comment on (or immediately above) the
  finding's line::

      x = thing.item()  # repro-lint: ignore[ANA001] -- host-side stats path

    The rationale after ``--`` is MANDATORY (a bare ``ignore`` is itself
    a finding, ANA000) and the CLI prints every suppression it honored,
    rationale included, so intent stays visible in CI logs.
    ``ignore[*]`` suppresses every rule on that line; a comma list
    (``ignore[ANA001,ANA003]``) suppresses several.

* **Baseline file** — ``tools/repro_lint_baseline.txt``, one
  ``path::rule::message`` key per line (line numbers excluded so the
  baseline survives unrelated edits).  The baseline exists to land the
  analyzer on a repo with pre-existing findings without fixing them all
  in one PR; this repo's baseline is kept EMPTY — new findings must be
  fixed or inline-suppressed with a rationale, not baselined.
  ``--write-baseline`` regenerates it from the current run.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.findings import Finding, make_finding

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*ignore\[([A-Za-z0-9*,\s]+)\]"
    r"(?:\s*--\s*(?P<why>.+?))?\s*$")


@dataclass(frozen=True)
class Suppression:
    line: int
    rules: Tuple[str, ...]       # ("*",) = every rule
    rationale: str               # "" = missing (ANA000)

    def covers(self, rule: str) -> bool:
        return "*" in self.rules or rule in self.rules


def scan_suppressions(path: str, source: str
                      ) -> Tuple[Dict[int, Suppression], List[Finding]]:
    """Parse inline suppression comments out of one file's source.

    Returns ``{line: Suppression}`` plus ANA000 findings for any
    suppression missing its rationale (those suppressions still apply —
    the missing-rationale finding itself is what fails the run, which
    reads better than the original finding resurfacing)."""
    out: Dict[int, Suppression] = {}
    problems: List[Finding] = []
    lines = source.splitlines()
    for lineno, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        why = (m.group("why") or "").strip()
        sup = Suppression(lineno, rules, why)
        out[lineno] = sup
        if text.lstrip().startswith("#"):
            # standalone comment (possibly a multi-line block): it
            # annotates the next code line, so anchor it there too
            j = lineno            # 0-based index of the line after it
            while j < len(lines) and (not lines[j].strip()
                                      or lines[j].lstrip().startswith("#")):
                j += 1
            if j < len(lines):
                out.setdefault(j + 1, sup)
        if not why:
            problems.append(make_finding(
                "ANA000", path, lineno,
                f"suppression ignore[{','.join(rules)}] has no rationale "
                f"(append `-- <why this is intentional>`)"))
    return out, problems


def apply_suppressions(findings: Iterable[Finding],
                       by_file: Dict[str, Dict[int, Suppression]]
                       ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (active, suppressed).

    A suppression covers its own line and the line directly below it, so
    the comment can sit either trailing the offending statement or on
    its own line above a statement too long to share one."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        sups = by_file.get(f.path, {})
        hit = None
        for line in (f.line, f.line - 1):
            s = sups.get(line)
            if s is not None and s.covers(f.rule):
                hit = s
                break
        if hit is None:
            active.append(f)
        else:
            suppressed.append(f.suppress(hit.rationale or "<no rationale>"))
    return active, suppressed


# -- baseline --------------------------------------------------------------

BASELINE_HEADER = (
    "# repro-lint baseline — `path::rule::message` keys the analyzer\n"
    "# ignores.  Kept EMPTY on purpose: fix new findings or suppress\n"
    "# inline with a rationale.  Regenerate: repro_lint --write-baseline.\n")


def load_baseline(path: str) -> Set[str]:
    try:
        with open(path, encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    except OSError:
        return set()
    return {ln.strip() for ln in lines
            if ln.strip() and not ln.lstrip().startswith("#")}


def write_baseline(path: str, findings: Iterable[Finding]) -> int:
    keys = sorted({f.baseline_key for f in findings})
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(BASELINE_HEADER)
        for k in keys:
            fh.write(k + "\n")
    return len(keys)


def apply_baseline(findings: Iterable[Finding], baseline: Set[str]
                   ) -> Tuple[List[Finding], List[Finding]]:
    active, known = [], []
    for f in findings:
        (known if f.baseline_key in baseline else active).append(f)
    return active, known
