from repro.data.loader import TaskDataset
from repro.data.tasks import TASKS, task_geometry
from repro.data.tokenizer import CharTokenizer

__all__ = ["TaskDataset", "TASKS", "task_geometry", "CharTokenizer"]
