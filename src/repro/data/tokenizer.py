"""Character tokenizer for the synthetic task suite.

Fixed vocabulary: printable task characters + special tokens.  The MASK id
is pinned to ``vocab_size - 1`` to match ``ModelConfig.mask_token_id``'s
default, PAD to 0.
"""
from __future__ import annotations

from typing import List

import numpy as np

_CHARS = "0123456789+-*/=()[]{}<>abcdefghijklmnopqrstuvwxyz ,.:|&^#@!?"


class CharTokenizer:
    PAD = 0

    def __init__(self, vocab_size: int = 128):
        assert vocab_size >= len(_CHARS) + 4
        self.vocab_size = vocab_size
        self._stoi = {c: i + 1 for i, c in enumerate(_CHARS)}
        self._itos = {i + 1: c for i, c in enumerate(_CHARS)}
        self.bos = len(_CHARS) + 1
        self.eos = len(_CHARS) + 2
        self.mask = vocab_size - 1

    def encode(self, s: str) -> List[int]:
        return [self._stoi[c] for c in s]

    def decode(self, ids) -> str:
        out = []
        for i in np.asarray(ids).tolist():
            if i in (self.PAD, self.bos, self.eos, self.mask):
                continue
            out.append(self._itos.get(int(i), "?"))
        return "".join(out)

    def pad_to(self, ids: List[int], length: int) -> List[int]:
        assert len(ids) <= length, (len(ids), length)
        return ids + [self.PAD] * (length - len(ids))
