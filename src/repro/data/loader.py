"""Batcher: task strings -> fixed-shape token batches.

Layout per example:  [BOS | prompt | answer | EOS | PAD…]  with a
``maskable`` indicator over the answer region (the diffusion corruption and
the loss touch only answer tokens — prompts are conditioning).
"""
from __future__ import annotations

import random
from typing import Dict, Iterator, Tuple

import numpy as np

from repro.data.tasks import TASKS, task_geometry
from repro.data.tokenizer import CharTokenizer


class TaskDataset:
    def __init__(self, task: str, tokenizer: CharTokenizer,
                 seq_len: int = 0, seed: int = 0):
        self.task = task
        self.tok = tokenizer
        self.gen = TASKS[task]
        self.prompt_len, self.answer_len = task_geometry(task)
        # [BOS prompt][answer EOS] — fixed geometry
        need = 1 + self.prompt_len + self.answer_len + 1
        self.seq_len = seq_len or need
        assert self.seq_len >= need, (self.seq_len, need)
        self.seed = seed

    @property
    def answer_slice(self) -> slice:
        lo = 1 + self.prompt_len
        return slice(lo, lo + self.answer_len)

    def encode_example(self, prompt: str, answer: str
                       ) -> Tuple[np.ndarray, np.ndarray]:
        t = self.tok
        ids = [t.bos] + t.encode(prompt) + t.encode(answer) + [t.eos]
        ids = t.pad_to(ids, self.seq_len)
        maskable = np.zeros(self.seq_len, bool)
        # the whole tail (answer + EOS + padding) is generation territory so
        # the model also learns to emit EOS/PAD at inference time
        maskable[self.answer_slice.start:] = True
        return np.asarray(ids, np.int32), maskable

    def batches(self, batch_size: int, seed: int = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = random.Random(self.seed if seed is None else seed)
        while True:
            toks, masks, answers = [], [], []
            for _ in range(batch_size):
                p, a = self.gen(rng)
                ids, maskable = self.encode_example(p, a)
                toks.append(ids)
                masks.append(maskable)
                answers.append(a)
            yield {"tokens": np.stack(toks), "maskable": np.stack(masks),
                   "answers": answers}

    def eval_batch(self, batch_size: int, seed: int = 10_000
                   ) -> Dict[str, np.ndarray]:
        """A held-out batch (disjoint seed stream from training)."""
        return next(self.batches(batch_size, seed=seed))

    def prompts_only(self, batch: Dict[str, np.ndarray]) -> np.ndarray:
        """[BOS prompt] prefix for inference-time generation."""
        return batch["tokens"][:, : 1 + self.prompt_len]

    def exact_match(self, generated: np.ndarray,
                    batch: Dict[str, np.ndarray]) -> float:
        """Fraction of examples whose decoded answer region matches."""
        sl = self.answer_slice
        want = batch["tokens"][:, sl]
        got = np.asarray(generated)[:, sl]
        return float(np.mean(np.all(want == got, axis=1)))
