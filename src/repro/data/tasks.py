"""Synthetic task suite — the band-2 quality testbed.

No public LLDM weights can be loaded in this container, so the paper's
quality claims are gated on small masked-diffusion LMs trained from scratch
on tasks whose answers are *bidirectionally constrained* — decode order
provably matters, which is exactly the regime FDM targets:

* ``sum``      a+b with carries: low digits are locally easy, high digits
               depend on carry chains — committing them too early is the
               canonical order-induced error.
* ``sort``     output = sorted input digits: every position constrains all
               others through the global multiset.
* ``parity``   copy the bits, then append block parities: copies are easy,
               parities depend on everything.
* ``bracket``  close a bracket prefix: the correct token at position i
               depends on the entire suffix structure.
* ``reverse``  output = reversed input (sanity task, order-insensitive).

Each task emits fixed-geometry (prompt, answer) strings so batches are
static shapes.  Difficulty knobs are module constants.
"""
from __future__ import annotations

import random
from typing import Callable, Dict, List, Tuple

SUM_DIGITS = 2          # operands up to 10^2-1, answer width 3
SORT_LEN = 12
PARITY_BITS = 9
PARITY_BLOCKS = 3
BRACKET_LEN = 10
REVERSE_LEN = 12


def _sum_example(rng: random.Random) -> Tuple[str, str]:
    a = rng.randrange(10 ** SUM_DIGITS)
    b = rng.randrange(10 ** SUM_DIGITS)
    prompt = f"{a:0{SUM_DIGITS}d}+{b:0{SUM_DIGITS}d}="
    answer = f"{a + b:0{SUM_DIGITS + 1}d}"
    return prompt, answer


def _sort_example(rng: random.Random) -> Tuple[str, str]:
    digits = [rng.randrange(10) for _ in range(SORT_LEN)]
    prompt = "".join(map(str, digits)) + ">"
    answer = "".join(map(str, sorted(digits)))
    return prompt, answer


def _parity_example(rng: random.Random) -> Tuple[str, str]:
    bits = [rng.randrange(2) for _ in range(PARITY_BITS)]
    prompt = "".join(map(str, bits)) + "="
    per = PARITY_BITS // PARITY_BLOCKS
    pars = [str(sum(bits[i * per:(i + 1) * per]) % 2)
            for i in range(PARITY_BLOCKS)]
    answer = "".join(map(str, bits)) + "".join(pars)
    return prompt, answer


def _bracket_example(rng: random.Random) -> Tuple[str, str]:
    """A prefix of opens/closes that needs exactly BRACKET_LEN closers,
    mixing () and [] so the *type* of each closer is order-constrained."""
    kinds = "([" if rng.random() < 0.9 else "(("
    stack: List[str] = []
    prefix = []
    while len(stack) < BRACKET_LEN:
        c = rng.choice(kinds)
        prefix.append(c)
        stack.append(c)
        # occasionally close one early to vary structure
        if stack and rng.random() < 0.25 and len(prefix) < 2 * BRACKET_LEN - 2:
            top = stack.pop()
            prefix.append(")" if top == "(" else "]")
            if len(stack) == 0:
                continue
    prompt = "".join(prefix)[-2 * BRACKET_LEN:] or "("
    # recompute the open stack of the (possibly trimmed) prompt
    stack = []
    for c in prompt:
        if c in "([":
            stack.append(c)
        elif stack:
            stack.pop()
    answer = "".join(")" if c == "(" else "]" for c in reversed(stack))
    answer = answer[:BRACKET_LEN].ljust(BRACKET_LEN, ".")
    prompt = prompt.rjust(2 * BRACKET_LEN, ".")
    return prompt + "=", answer


def _reverse_example(rng: random.Random) -> Tuple[str, str]:
    s = "".join(rng.choice("abcdefghij") for _ in range(REVERSE_LEN))
    return s + "<", s[::-1]


TASKS: Dict[str, Callable[[random.Random], Tuple[str, str]]] = {
    "sum": _sum_example,
    "sort": _sort_example,
    "parity": _parity_example,
    "bracket": _bracket_example,
    "reverse": _reverse_example,
}


def task_geometry(task: str) -> Tuple[int, int]:
    """(prompt_len, answer_len) — fixed per task for static batch shapes."""
    rng = random.Random(0)
    p, a = TASKS[task](rng)
    return len(p), len(a)
