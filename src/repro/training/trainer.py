"""The training loop: Eq. 4 masked-diffusion objective + AdamW.

``make_train_step`` builds the jitted step shared by the trainer, the
examples and the multi-pod dry-run (the same function lowers on the
production mesh — there is exactly one training semantics in the repo).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.loss import masked_cross_entropy, token_accuracy
from repro.core.masking import apply_mask, sample_mask_ratio
from repro.models.model import forward, init_model
from repro.training.optimizer import (AdamWState, adamw_init, adamw_update,
                                      cosine_schedule)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    extra_inputs: Tuple[str, ...] = (),
                    bf16_params: bool = False,
                    microbatch: int = 1) -> Callable:
    """Returns train_step(params, opt_state, rng, batch) -> (params, opt,
    metrics).  ``batch`` = {tokens, maskable(bool), **extra_inputs}.

    ``bf16_params=True`` casts the f32 master weights to bf16 ONCE at the
    top of the step (a cheap sharded elementwise op) so every FSDP
    all-gather moves bf16, halving the dominant collective term — the
    standard mixed-precision ZeRO trick; the optimizer still updates the
    f32 masters.
    """
    sched = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps)

    def loss_fn(params, rng, batch):
        tokens = batch["tokens"]
        maskable = batch["maskable"]
        if bf16_params:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
        r1, r2 = jax.random.split(rng)
        t = sample_mask_ratio(r1, tokens.shape[0])
        corrupted, masked = apply_mask(r2, tokens, t, cfg, maskable)
        kw = {k: batch[k] for k in extra_inputs}
        logits, aux = forward(params, corrupted, cfg, **kw)
        loss, _ = masked_cross_entropy(logits, tokens, masked, t)
        acc = token_accuracy(logits, tokens, masked)
        return loss + aux, {"loss": loss, "aux": aux, "acc": acc}

    def train_step(params, opt_state: AdamWState, rng, batch):
        if microbatch > 1:
            # gradient accumulation: scan over microbatches, summing grads
            # — activations (the MoE dispatch buffers especially) shrink by
            # the microbatch factor at the cost of `microbatch` sequential
            # passes (§Perf A5)
            mb = jax.tree.map(
                lambda a: a.reshape(microbatch, a.shape[0] // microbatch,
                                    *a.shape[1:]), batch)

            def body(acc, xs):
                i, m = xs
                g, met = jax.grad(loss_fn, has_aux=True)(
                    params, jax.random.fold_in(rng, i), m)
                return jax.tree.map(jnp.add, acc, g), met

            zero = jax.tree.map(jnp.zeros_like, params)
            grads, mets = jax.lax.scan(
                body, zero, (jnp.arange(microbatch), mb))
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = jax.tree.map(lambda a: jnp.mean(a, axis=0), mets)
        else:
            grads, metrics = jax.grad(loss_fn, has_aux=True)(params, rng,
                                                             batch)
        params, opt_state = adamw_update(
            grads, opt_state, params, sched,
            weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm)
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, tcfg: TrainConfig, batches,
          params=None, log: Optional[Callable[[str], None]] = print,
          eval_fn: Optional[Callable] = None) -> Tuple[dict, Dict]:
    """Run ``tcfg.steps`` steps over the ``batches`` iterator."""
    rng = jax.random.PRNGKey(tcfg.seed)
    if params is None:
        rng, init_rng = jax.random.split(rng)
        params = init_model(init_rng, cfg)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    history = {"loss": [], "acc": []}
    t0 = time.perf_counter()
    for step in range(1, tcfg.steps + 1):
        batch = next(batches)
        batch = {"tokens": jnp.asarray(batch["tokens"]),
                 "maskable": jnp.asarray(batch["maskable"])}
        rng, step_rng = jax.random.split(rng)
        params, opt_state, metrics = step_fn(params, opt_state, step_rng,
                                             batch)
        if step % tcfg.log_every == 0 or step == 1 or step == tcfg.steps:
            m = jax.device_get(metrics)
            history["loss"].append(float(m["loss"]))
            history["acc"].append(float(m["acc"]))
            if log:
                log(f"step {step:5d}  loss {m['loss']:.4f}  "
                    f"masked-acc {m['acc']:.3f}  "
                    f"({(time.perf_counter() - t0):.1f}s)")
        if eval_fn and step % tcfg.eval_every == 0:
            eval_fn(params, step)
    if tcfg.ckpt_dir:
        from repro.training.checkpoint import save
        save(f"{tcfg.ckpt_dir}/final.npz", params, opt_state, tcfg.steps)
    return params, history
