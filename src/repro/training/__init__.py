from repro.training.checkpoint import load, save
from repro.training.optimizer import (AdamWState, adamw_init, adamw_update,
                                      cosine_schedule, global_norm)
from repro.training.trainer import make_train_step, train

__all__ = ["load", "save", "AdamWState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "make_train_step", "train"]
