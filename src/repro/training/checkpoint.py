"""Checkpointing: pytree <-> flat .npz with structure-path keys."""
from __future__ import annotations

import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, params: Any, opt_state: Any = None,
         step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    payload["meta/step"] = np.asarray(step)
    np.savez_compressed(path, **payload)


def load(path: str, params_template: Any,
         opt_template: Any = None) -> Tuple[Any, Any, int]:
    """Restore into the given pytree templates (shape/dtype-checked)."""
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}

    def restore(template, prefix):
        flat = _flatten(template)
        out = {}
        for k, ref in flat.items():
            arr = data[f"{prefix}/{k}"]
            assert arr.shape == ref.shape, (k, arr.shape, ref.shape)
            out[k] = arr
        leaves_paths = jax.tree_util.tree_flatten_with_path(template)
        keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path) for path, _ in leaves_paths[0]]
        return jax.tree_util.tree_unflatten(
            leaves_paths[1], [out[k] for k in keys])

    params = restore(params_template, "params")
    opt = restore(opt_template, "opt") if opt_template is not None else None
    return params, opt, int(data["meta/step"])
