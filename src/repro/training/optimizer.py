"""AdamW + cosine-with-warmup LR schedule, pure JAX (no optax dependency)."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def cosine_schedule(lr: float, warmup: int, total: int):
    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * jnp.where(s < warmup, warm, cos)
    return sched


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.zeros_like, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, sched,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.01, clip_norm: float = 1.0
                 ) -> Tuple[dict, AdamWState]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = sched(step)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
