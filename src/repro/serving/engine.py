"""Batched serving engine for diffusion-LM decoding.

A miniature vLLM-style front end adapted to the *blockwise* execution model
of masked-diffusion decoding: requests are queued, grouped into fixed-shape
batches, and each batch is decoded through a single ``repro.core.Decoder``
— the first-class decode stack that owns the device-resident fused block
loop, the strategy registry, and the params-keyed cross-call runner cache.
Because that cache is shared and weak, the engine no longer keeps its own
per-sequence-length jit table: repeat batches of any shape reuse the
Decoder's compilations, and dropping an engine (or hot-swapping weights by
building a new one) releases them — the prerequisite for long-lived
multi-model serving.  Diffusion decode is batch-synchronous (every
sequence in the batch advances through the same denoising steps), so the
natural scheduling unit is the *batch*, not the token — continuous
batching applies between blocks, not between tokens.

Scheduling is *prompt-length bucketed*: the queue is scanned into buckets
(prompt length rounded up to ``length_bucket``), shorter prompts in the
chosen batch left-padded with mask tokens — the natural pad for a
masked-diffusion LM, which reads mask as "unknown context" — and the
bucket holding the oldest request is served first.  A single odd-length
prompt at the head therefore cannot strand the rest of the queue.  Padding
stops at the batch's max real length, not the bucket ceiling: mask pads
carry a measurable quality cost (DESIGN.md), so uniform-length workloads
see zero padding.

Per-request decode knobs: ``submit`` accepts ``strategy`` / ``steps`` /
``gen_length`` / ``block_size`` overrides (validated against the strategy
registry and the block geometry at the submission boundary, where a clear
error can still reach the caller).  The effective ``DecodeConfig`` is part
of the bucket key, so only requests decoding identically share a batch —
the ParallelBench observation that dLLM quality/latency trade-offs are
workload-dependent means these knobs must reach the server boundary, and
batching across them would silently decode somebody with somebody else's
settings.

The engine itself is synchronous and single-threaded on purpose; the
batch-selection / batch-decode split (``select_batch`` /
``decode_batch`` / ``decode_batch_blocks``) is what the async scheduler
(``repro.serving.scheduler``) builds its continuous-batching loop on:
selection and queue mutation stay on the event-loop thread, only the
block-grain dispatches run on a worker thread.

Streaming: pass ``on_block_committed(requests, block_index, lo, hi, x)``
to the constructor to observe each committed block of a batch as it lands
(the natural SSE grain for diffusion decoding — tokens inside a block
finalize together).  ``x`` is the live device canvas; don't block in the
callback.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.decoder import Decoder, SampleStats, validate_cache_policy
from repro.core.strategies import resolve_strategy
from repro.serving.faults import FaultInjector, validate_block_tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (Lp,) int32
    result: Optional[np.ndarray] = None
    stats: Optional[SampleStats] = None
    submit_time: float = 0.0
    finish_time: float = 0.0
    dcfg: Optional[DecodeConfig] = None   # effective per-request config
    deadline: Optional[float] = None      # absolute perf_counter() time by
                                          # which decoding must have STARTED
    cancelled: bool = False
    expired: bool = False
    failed: bool = False                  # quarantined / retries exhausted
    pad_cols: int = 0                     # mask pad columns this request got
    retries: int = 0                      # supervision re-queues so far
    group: int = 0                        # bisection cohort (requests only
                                          # co-batch within a group; fresh
                                          # ids keep a failed batch's halves
                                          # from re-merging)

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def status(self) -> str:
        if self.cancelled:
            return "cancelled"
        if self.expired:
            return "expired"
        if self.failed:
            return "error"
        return "done" if self.result is not None else "queued"


@dataclasses.dataclass
class Batch:
    """One schedulable unit: same effective DecodeConfig, same length
    bucket, padded to fixed shape.  Produced by ``select_batch``,
    consumed by ``decode_batch`` / ``decode_batch_blocks``."""
    requests: List[Request]
    prompts: np.ndarray                # (max_batch, Lp) — replicas included
    pads: List[int]                    # per-request mask pad columns
    dcfg: DecodeConfig
    rng: jax.Array


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, dcfg: DecodeConfig,
                 max_batch: int = 8, seed: int = 0,
                 length_bucket: int = 8,
                 on_block_committed: Optional[Callable] = None,
                 fault_injector: Optional[FaultInjector] = None):
        self.params = params
        self.cfg = cfg
        self.dcfg = dcfg
        self.decoder = Decoder(params, cfg, dcfg)
        self.max_batch = max_batch
        self.length_bucket = max(length_bucket, 1)
        self.on_block_committed = on_block_committed
        # observability hook (installed by the async scheduler):
        # ``(requests, block_index, t_start_s, t_end_s)`` per KV-cache
        # refresh inside ``decode_batch_blocks``
        self.on_cache_refresh: Optional[Callable] = None
        self.fault_injector = fault_injector
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._next_id = 0
        self._next_group = 1
        self._rng = jax.random.PRNGKey(seed)
        self._decoders: Dict[DecodeConfig, Decoder] = {dcfg: self.decoder}

    def set_fault_injector(self,
                           injector: Optional[FaultInjector]) -> None:
        """Attach (or detach) the deterministic fault-injection harness;
        it fires inside ``decode_batch_blocks`` — the supervision
        grain."""
        self.fault_injector = injector

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, *,
               strategy: Optional[str] = None,
               steps: Optional[int] = None,
               gen_length: Optional[int] = None,
               block_size: Optional[int] = None,
               cache_policy: Optional[str] = None,
               trace: Optional[bool] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue a prompt; returns the request id.

        The keyword overrides build this request's effective
        ``DecodeConfig`` (validated HERE — an unknown strategy, an
        infeasible geometry, or a cache policy the model cannot serve
        raises at the submission boundary instead of deep inside a decode
        batch).  Requests only batch with requests sharing the same
        effective config.  ``deadline_s`` bounds QUEUE time: a request
        still queued after it is dropped as expired at the next batch
        selection (admission control for overload — decode work is never
        wasted on a request whose client gave up).
        """
        over = {k: v for k, v in dict(
            strategy=strategy, steps=steps, gen_length=gen_length,
            block_size=block_size, cache_policy=cache_policy,
            trace=trace).items() if v is not None}
        # replace() re-runs DecodeConfig.__post_init__, so an unknown
        # cache_policy raises ValueError right here
        dcfg = dataclasses.replace(self.dcfg, **over) if over else self.dcfg
        resolve_strategy(dcfg.strategy)          # KeyError on unknown name
        validate_cache_policy(self.cfg, dcfg)    # arch can serve the policy?
        for knob in ("gen_length", "block_size", "steps"):
            if getattr(dcfg, knob) < 1:
                raise ValueError(f"{knob}={getattr(dcfg, knob)} must be "
                                 f"a positive integer")
        if dcfg.gen_length % dcfg.block_size:
            raise ValueError(
                f"gen_length={dcfg.gen_length} is not a multiple of "
                f"block_size={dcfg.block_size}")
        num_blocks = dcfg.gen_length // dcfg.block_size
        if dcfg.steps < num_blocks:
            raise ValueError(
                f"steps={dcfg.steps} is infeasible: {num_blocks} blocks "
                f"need at least one step each")
        rid = self._next_id
        self._next_id += 1
        now = time.perf_counter()
        self.queue.append(Request(
            rid=rid, prompt=np.asarray(prompt), submit_time=now, dcfg=dcfg,
            deadline=None if deadline_s is None else now + deadline_s))
        return rid

    def cancel(self, rid: int) -> bool:
        """Drop a still-queued request.  Returns True if it was removed
        (it lands in ``done`` with ``cancelled=True`` and no result);
        False if it already finished, was never submitted, or is decoding
        right now (a running batch is batch-synchronous and cannot be
        preempted — the result simply arrives and is kept)."""
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                req.cancelled = True
                req.finish_time = time.perf_counter()
                self.done[rid] = req
                return True
        return False

    def result(self, rid: int) -> Request:
        return self.done[rid]

    @property
    def queue_depth(self) -> int:
        """Queued (not yet decoding) requests — the backpressure signal."""
        return len(self.queue)

    # -- scheduler ---------------------------------------------------------
    def _bucket_len(self, lp: int) -> int:
        """Round a prompt length up to its bucket ceiling."""
        q = self.length_bucket
        return -(-lp // q) * q

    def _bucket_key(self, req: Request) -> Tuple:
        """Requests batch together iff this matches: same prompt-length
        bucket AND same effective DecodeConfig (frozen → hashable) AND
        same bisection cohort (supervision re-queues a failed batch's
        halves under fresh group ids precisely so they cannot re-merge
        into the batch that just failed).

        ``cache_policy`` appears explicitly even though ``dcfg`` already
        subsumes it: policies decode through DIFFERENT executables with
        different numerics (dual is approximate), so mixed-policy
        co-batching would be a correctness bug, not a batching
        inefficiency — the explicit key component keeps that invariant
        standing if the effective-config keying is ever relaxed."""
        return (self._bucket_len(req.prompt.shape[0]), req.dcfg,
                req.dcfg.cache_policy, req.group)

    # -- supervision hooks (used by the async scheduler) -------------------
    def requeue(self, requests: List[Request],
                fresh_group: bool = False) -> None:
        """Push requests back at the queue FRONT, preserving their order
        (retried work should not queue behind traffic that arrived after
        it).  ``fresh_group=True`` moves the cohort to a new bisection
        group id — the half of a failed batch must never re-co-batch
        with the other half."""
        if fresh_group:
            group = self._next_group
            self._next_group += 1
            for req in requests:
                req.group = group
        for req in reversed(list(requests)):
            req.pad_cols = 0            # re-derived at the next select
            self.queue.appendleft(req)

    def record_failed(self, req: Request,
                      now: Optional[float] = None) -> None:
        """Terminal supervision failure (quarantine / retries exhausted):
        the request lands in ``done`` with no result, visible to
        ``result(rid)`` and excluded from throughput accounting exactly
        like a cancelled one."""
        req.failed = True
        req.finish_time = time.perf_counter() if now is None else now
        self.done[req.rid] = req

    def adopt(self, old: "ServingEngine") -> None:
        """Carry another engine's in-flight bookkeeping into this one —
        the supervisor's engine-rebuild path: queued requests (their
        effective configs ride along), finished history, and the rid /
        bisection-group counters, so streams and ``result(rid)`` survive
        the swap.  The fault injector and hooks are NOT adopted: the
        rebuilt engine starts with whatever its factory installed."""
        self.queue.extend(old.queue)
        old.queue.clear()
        self.done.update(old.done)
        self._next_id = max(self._next_id, old._next_id)
        self._next_group = max(self._next_group, old._next_group)

    def reap_expired(self, now: Optional[float] = None) -> List[Request]:
        """Drop queued requests whose deadline passed; returns them (also
        recorded in ``done`` with ``expired=True``)."""
        now = time.perf_counter() if now is None else now
        expired = [r for r in self.queue
                   if r.deadline is not None and now > r.deadline]
        for req in expired:
            self.queue.remove(req)
            req.expired = True
            req.finish_time = now
            self.done[req.rid] = req
        return expired

    def select_batch(self) -> Optional[Batch]:
        """Pop one batch from the queue (no decoding).

        The whole queue is scanned into (prompt-length bucket, effective
        DecodeConfig) groups and the group containing the OLDEST request
        is served (up to max_batch, FIFO within the group) — no
        head-of-line blocking on one odd-length prompt or one exotic
        per-request override.  Prompts shorter than the batch's longest
        are left-padded with the mask token; the pad columns sit outside
        every decode block, so they are never committed, and are sliced
        off the per-request results.

        Callers reap expired requests FIRST (``step`` does; the async
        scheduler does too, emitting terminal events for them) — this
        method deliberately does not, so a request can never slip into
        ``done`` unobserved between a caller's reap and its select.
        """
        if not self.queue:
            return None
        head = self._bucket_key(self.queue[0])
        batch: List[Request] = []
        rest: List[Request] = []
        for r in self.queue:
            if self._bucket_key(r) == head and len(batch) < self.max_batch:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = deque(rest)
        # pad only to the batch's max REAL length (≤ the bucket ceiling):
        # mask pads carry a quality cost — the model reads mask count as a
        # length signal (measured: 8 pads cost 78%→47% EM on the sum
        # testbed) — so uniform-length workloads must see zero padding
        lp = max(r.prompt.shape[0] for r in batch)
        pads = [lp - r.prompt.shape[0] for r in batch]
        for r, p in zip(batch, pads):
            r.pad_cols = p
        prompts = np.stack([
            np.concatenate([np.full((p,), self.cfg.mask_token_id,
                                    r.prompt.dtype), r.prompt])
            if p else r.prompt for r, p in zip(batch, pads)])
        # pad the batch to the bucket size (replicate last prompt)
        pad = self.max_batch - len(batch)
        if pad:
            prompts = np.concatenate(
                [prompts, np.repeat(prompts[-1:], pad, 0)])
        self._rng, rng = jax.random.split(self._rng)
        return Batch(requests=batch, prompts=prompts, pads=pads,
                     dcfg=batch[0].dcfg or self.dcfg, rng=rng)

    def _decoder_for(self, dcfg: DecodeConfig) -> Decoder:
        dec = self._decoders.get(dcfg)
        if dec is None:
            # Decoders are cheap (compiled runners live in the shared
            # weak cache keyed on the weights), but keep a small table so
            # repeat overrides don't even re-key
            if len(self._decoders) > 32:
                self._decoders.clear()
                self._decoders[self.dcfg] = self.decoder
            dec = self._decoders[dcfg] = Decoder(self.params, self.cfg,
                                                 dcfg)
        return dec

    def decode_batch(self, batch: Batch,
                     on_block_committed: Optional[Callable] = None
                     ) -> List[int]:
        """Decode one selected batch to completion (single dispatch when
        the whole-request driver applies).  Returns finished rids."""
        cb = None
        if on_block_committed is not None:
            def cb(blk, lo, hi, x):
                return on_block_committed(batch.requests, blk, lo, hi, x)
        dec = self._decoder_for(batch.dcfg)
        out, stats = dec.generate(batch.rng, jnp.asarray(batch.prompts),
                                  on_block_committed=cb)
        return self._finish_batch(batch, out, stats)

    def decode_batch_blocks(self, batch: Batch) -> Iterator[Tuple]:
        """Decode one selected batch at the BLOCK grain: a generator
        yielding ``(block_index, lo, hi, block_tokens)`` after each
        committed block — ``block_tokens`` is the host-side ``(B, bs)``
        token slice (replica rows included), ready to fan out to
        per-request streams — and returning the finished rids.

        Between yields the caller owns the host (the engine is built on
        ``Decoder.generate_blocks``): the async scheduler runs each
        resumption on a worker thread and uses the gaps to deliver
        events and keep its event loop live.  The engine-level
        ``on_block_committed`` hook fires here too, with the same
        signature as in ``decode_batch``.

        This is also the FAULT BOUNDARY: an attached ``FaultInjector``
        fires here (raised exceptions / simulated OOM / injected stalls
        before a block, NaN-style token corruption after it), and every
        committed block passes the always-on output validator
        (``CorruptOutputError`` on out-of-vocab tokens — the host-side
        signature of non-finite logits).  Failures therefore surface at
        a block boundary of a specific batch, which is the grain the
        supervision layer retries, bisects, and quarantines at.  A
        failed attempt never reaches ``_finish_batch``: results and
        stats only land on success, so a retried batch is
        bit-identical to a fault-free decode.
        """
        inj = self.fault_injector
        bi = inj.begin_batch() if inj is not None else 0
        rids = [r.rid for r in batch.requests]
        dec = self._decoder_for(batch.dcfg)
        if self.on_cache_refresh is not None:
            # decoders are per-config and the engine decodes one batch
            # at a time, so pointing the decoder hook at this batch's
            # requests is race-free
            dec.on_cache_refresh = (
                lambda blk, t0, t1, _reqs=batch.requests:
                self.on_cache_refresh(_reqs, blk, t0, t1))
        else:
            dec.on_cache_refresh = None
        blocks = dec.generate_blocks(batch.rng, jnp.asarray(batch.prompts))
        block_index = 0
        while True:
            if inj is not None:
                inj.before_block(bi, rids, block_index)
            try:
                ev = next(blocks)
            except StopIteration as fin:
                out, stats = fin.value
                return self._finish_batch(batch, out, stats)
            block_index += 1
            tokens = np.asarray(ev.x[:, ev.lo:ev.hi])
            if inj is not None:
                tokens = inj.filter_tokens(bi, rids, ev.block, tokens)
            validate_block_tokens(tokens, self.cfg.vocab_size)
            if self.on_block_committed is not None:
                self.on_block_committed(batch.requests, ev.block, ev.lo,
                                        ev.hi, ev.x)
            yield (ev.block, ev.lo, ev.hi, tokens)

    def _finish_batch(self, batch: Batch, out, stats: SampleStats
                      ) -> List[int]:
        out = np.asarray(jax.device_get(out))
        now = time.perf_counter()
        real = len(batch.requests)
        rows = len(batch.prompts)
        for i, req in enumerate(batch.requests):
            req.result = out[i, batch.pads[i]:]
            # per-request stats copy: each request gets its SHARE of the
            # batch's work — tokens (its own gen_length), forwards, and
            # wall time all divided across the real (non-pad-replicated)
            # members, so derived rates (tps, tokens_per_forward) come out
            # consistent: a request's tps equals the batch's aggregate
            # decode throughput, the rate it actually experienced.  The
            # seed pro-rated forwards only, leaving tps wrong by a factor
            # of `real`.  `steps` stays the true batch step count (every
            # request genuinely went through all of them — diffusion
            # decode is batch-synchronous); end-to-end latency lives in
            # Request.latency.
            # phase counts accumulate one flag per BATCH ROW per step —
            # pad replicas included — so normalise by the padded row
            # count: the per-example histogram, which keeps the
            # sum(phase_counts) == steps invariant per request and keeps
            # replica rows from inflating the reported phase work.
            # revocations / skipped_forwards are whole-batch totals like
            # forwards: each real request gets its share
            # the trace (dcfg.trace decodes only) is per-POSITION, not
            # pro-rated: each request gets its own row of the commit
            # maps, pad columns sliced off so commit_step indexes line
            # up with the request's own result coordinates
            req.stats = dataclasses.replace(
                stats,
                tokens_generated=batch.dcfg.gen_length,
                forward_equivalents=stats.forward_equivalents / real,
                wall_time=stats.wall_time / real,
                revocations=stats.revocations / real,
                skipped_forwards=stats.skipped_forwards / real,
                phase_counts={k: v / rows
                              for k, v in stats.phase_counts.items()},
                trace=stats.trace.slice_rows(i, batch.pads[i])
                if stats.trace is not None else None)
            req.finish_time = now
            self.done[req.rid] = req
        return [r.rid for r in batch.requests]

    def step(self) -> List[int]:
        """Serve one batch from the queue.  Returns finished request ids."""
        self.reap_expired()
        batch = self.select_batch()
        if batch is None:
            return []
        return self.decode_batch(batch, self.on_block_committed)

    def run_until_idle(self) -> None:
        while self.queue:
            self.step()

    # -- metrics -----------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Aggregate serving metrics over finished requests.

        Throughput accounting counts REAL requests only: `done` never
        holds pad replicas, and the per-request stats summed here were
        pro-rated across real batch members in `decode_batch`, so
        replicated rows (batches padded to `max_batch`) and mask pad
        columns inflate neither tokens nor forward-equivalents.
        Cancelled/expired requests never decoded, so they are excluded.

        `_finish_batch` may be inserting into `done` from the
        scheduler's worker thread while this runs on the event loop:
        snapshot via ``list(...)`` (one GIL-atomic op) before iterating
        so a mid-scrape batch completion cannot blow up the iteration.
        """
        reqs = [r for r in list(self.done.values())
                if r.stats is not None]
        if not reqs:
            return {}
        lat = [r.latency for r in reqs]
        # one stable stats form: aggregate over as_dict(), the same wire
        # shape the HTTP terminal event and the benchmarks read
        stats = [r.stats.as_dict() for r in reqs]
        toks = sum(s["tokens_generated"] for s in stats)
        fwds = sum(s["forward_equivalents"] for s in stats)
        decode_s = sum(s["wall_time_s"] for s in stats)
        span = max(r.finish_time for r in reqs) - \
            min(r.submit_time for r in reqs)
        return {"requests": len(reqs),
                "mean_latency_s": float(np.mean(lat)),
                "p95_latency_s": float(np.percentile(lat, 95)),
                "throughput_tps": toks / max(span, 1e-9),
                "decode_tps": toks / max(decode_s, 1e-9),
                "forward_equivalents": float(fwds),
                "revocations": float(sum(s["revocations"]
                                         for s in stats)),
                "skipped_forwards": float(sum(s["skipped_forwards"]
                                              for s in stats))}
