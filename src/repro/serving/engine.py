"""Batched serving engine for diffusion-LM decoding.

A miniature vLLM-style front end adapted to the *blockwise* execution model
of masked-diffusion decoding: requests are queued, grouped into fixed-shape
batches (padding to the bucket size keeps one jit compilation alive), and
each batch is decoded with the configured strategy through the semi-AR
sampler.  Diffusion decode is batch-synchronous (every sequence in the
batch advances through the same denoising steps), so the natural scheduling
unit is the *batch*, not the token — continuous batching applies between
blocks, not between tokens.

The engine also owns the per-batch model function cache (one jitted forward
per sequence length) — the serving analogue of a KV-cache manager for
bidirectional models where the cache is the *committed prefix* itself.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.sampler import SampleStats, generate
from repro.models.model import forward


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (Lp,) int32
    result: Optional[np.ndarray] = None
    stats: Optional[SampleStats] = None
    submit_time: float = 0.0
    finish_time: float = 0.0

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, dcfg: DecodeConfig,
                 max_batch: int = 8, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.dcfg = dcfg
        self.max_batch = max_batch
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._next_id = 0
        self._rng = jax.random.PRNGKey(seed)
        self._model_fns: Dict[int, Callable] = {}

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid=rid, prompt=np.asarray(prompt),
                                  submit_time=time.perf_counter()))
        return rid

    def result(self, rid: int) -> Request:
        return self.done[rid]

    # -- scheduler ---------------------------------------------------------
    def _model_fn(self, seq_len: int) -> Callable:
        if seq_len not in self._model_fns:
            cfg = self.cfg
            params = self.params
            self._model_fns[seq_len] = jax.jit(
                lambda x: forward(params, x, cfg)[0])
        return self._model_fns[seq_len]

    def step(self) -> List[int]:
        """Serve one batch from the queue. Returns finished request ids."""
        if not self.queue:
            return []
        batch: List[Request] = []
        lp = self.queue[0].prompt.shape[0]
        while self.queue and len(batch) < self.max_batch \
                and self.queue[0].prompt.shape[0] == lp:
            batch.append(self.queue.popleft())
        # pad the batch to the bucket size (replicate last prompt)
        prompts = np.stack([r.prompt for r in batch])
        pad = self.max_batch - len(batch)
        if pad:
            prompts = np.concatenate(
                [prompts, np.repeat(prompts[-1:], pad, 0)])
        model_fn = self._model_fn(lp + self.dcfg.gen_length)
        self._rng, rng = jax.random.split(self._rng)
        out, stats = generate(rng, model_fn, jnp.asarray(prompts),
                              self.cfg, self.dcfg)
        out = np.asarray(jax.device_get(out))
        now = time.perf_counter()
        for i, req in enumerate(batch):
            req.result = out[i]
            req.stats = stats
            req.finish_time = now
            self.done[req.rid] = req
        return [r.rid for r in batch]

    def run_until_idle(self) -> None:
        while self.queue:
            self.step()

    # -- metrics -----------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        reqs = list(self.done.values())
        if not reqs:
            return {}
        lat = [r.latency for r in reqs]
        toks = sum(self.dcfg.gen_length for _ in reqs)
        span = max(r.finish_time for r in reqs) - \
            min(r.submit_time for r in reqs)
        return {"requests": len(reqs),
                "mean_latency_s": float(np.mean(lat)),
                "p95_latency_s": float(np.percentile(lat, 95)),
                "throughput_tps": toks / max(span, 1e-9)}
