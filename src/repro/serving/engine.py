"""Batched serving engine for diffusion-LM decoding.

A miniature vLLM-style front end adapted to the *blockwise* execution model
of masked-diffusion decoding: requests are queued, grouped into fixed-shape
batches, and each batch is decoded through a single ``repro.core.Decoder``
— the first-class decode stack that owns the device-resident fused block
loop, the strategy registry, and the params-keyed cross-call runner cache.
Because that cache is shared and weak, the engine no longer keeps its own
per-sequence-length jit table: repeat batches of any shape reuse the
Decoder's compilations, and dropping an engine (or hot-swapping weights by
building a new one) releases them — the prerequisite for long-lived
multi-model serving.  Diffusion decode is batch-synchronous (every
sequence in the batch advances through the same denoising steps), so the
natural scheduling unit is the *batch*, not the token — continuous
batching applies between blocks, not between tokens.

Scheduling is *prompt-length bucketed*: the queue is scanned into buckets
(prompt length rounded up to ``length_bucket``), shorter prompts in the
chosen batch left-padded with mask tokens — the natural pad for a
masked-diffusion LM, which reads mask as "unknown context" — and the
bucket holding the oldest request is served first.  A single odd-length
prompt at the head therefore cannot strand the rest of the queue.  Padding
stops at the batch's max real length, not the bucket ceiling: mask pads
carry a measurable quality cost (DESIGN.md), so uniform-length workloads
see zero padding.

Streaming: pass ``on_block_committed(requests, block_index, lo, hi, x)``
to the constructor to observe each committed block of a batch as it lands
(the natural SSE grain for diffusion decoding — tokens inside a block
finalize together).  ``x`` is the live device canvas; don't block in the
callback.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DecodeConfig, ModelConfig
from repro.core.decoder import Decoder, SampleStats


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (Lp,) int32
    result: Optional[np.ndarray] = None
    stats: Optional[SampleStats] = None
    submit_time: float = 0.0
    finish_time: float = 0.0

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, dcfg: DecodeConfig,
                 max_batch: int = 8, seed: int = 0,
                 length_bucket: int = 8,
                 on_block_committed: Optional[Callable] = None):
        self.params = params
        self.cfg = cfg
        self.dcfg = dcfg
        self.decoder = Decoder(params, cfg, dcfg)
        self.max_batch = max_batch
        self.length_bucket = max(length_bucket, 1)
        self.on_block_committed = on_block_committed
        self.queue: Deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._next_id = 0
        self._rng = jax.random.PRNGKey(seed)

    # -- client API --------------------------------------------------------
    def submit(self, prompt: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid=rid, prompt=np.asarray(prompt),
                                  submit_time=time.perf_counter()))
        return rid

    def result(self, rid: int) -> Request:
        return self.done[rid]

    # -- scheduler ---------------------------------------------------------
    def _bucket_len(self, lp: int) -> int:
        """Round a prompt length up to its bucket ceiling."""
        q = self.length_bucket
        return -(-lp // q) * q

    def step(self) -> List[int]:
        """Serve one batch from the queue. Returns finished request ids.

        The whole queue is scanned into prompt-length buckets and the
        bucket containing the oldest request is served (up to max_batch,
        FIFO within the bucket) — no head-of-line blocking on one
        odd-length prompt.  Prompts shorter than the batch's longest are
        left-padded with the mask token; the pad columns sit outside every
        decode block, so they are never committed, and are sliced off the
        per-request results.
        """
        if not self.queue:
            return []
        head = self._bucket_len(self.queue[0].prompt.shape[0])
        batch: List[Request] = []
        rest: List[Request] = []
        for r in self.queue:
            if self._bucket_len(r.prompt.shape[0]) == head \
                    and len(batch) < self.max_batch:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = deque(rest)
        # pad only to the batch's max REAL length (≤ the bucket ceiling):
        # mask pads carry a quality cost — the model reads mask count as a
        # length signal (measured: 8 pads cost 78%→47% EM on the sum
        # testbed) — so uniform-length workloads must see zero padding
        lp = max(r.prompt.shape[0] for r in batch)
        pads = [lp - r.prompt.shape[0] for r in batch]
        prompts = np.stack([
            np.concatenate([np.full((p,), self.cfg.mask_token_id,
                                    r.prompt.dtype), r.prompt])
            if p else r.prompt for r, p in zip(batch, pads)])
        # pad the batch to the bucket size (replicate last prompt)
        pad = self.max_batch - len(batch)
        if pad:
            prompts = np.concatenate(
                [prompts, np.repeat(prompts[-1:], pad, 0)])
        self._rng, rng = jax.random.split(self._rng)
        cb = None
        if self.on_block_committed is not None:
            def cb(blk, lo, hi, x):
                return self.on_block_committed(batch, blk, lo, hi, x)
        out, stats = self.decoder.generate(rng, jnp.asarray(prompts),
                                           on_block_committed=cb)
        out = np.asarray(jax.device_get(out))
        now = time.perf_counter()
        real = len(batch)
        for i, req in enumerate(batch):
            req.result = out[i, pads[i]:]
            # per-request stats copy: each request gets its SHARE of the
            # batch's work — tokens (its own gen_length), forwards, and
            # wall time all divided across the real (non-pad-replicated)
            # members, so derived rates (tps, tokens_per_forward) come out
            # consistent: a request's tps equals the batch's aggregate
            # decode throughput, the rate it actually experienced.  The
            # seed pro-rated forwards only, leaving tps wrong by a factor
            # of `real`.  `steps` stays the true batch step count (every
            # request genuinely went through all of them — diffusion
            # decode is batch-synchronous); end-to-end latency lives in
            # Request.latency.
            # phase counts accumulate one flag per BATCH ROW per step —
            # pad replicas included — so normalise by the padded row
            # count: the per-example histogram, which keeps the
            # sum(phase_counts) == steps invariant per request and keeps
            # replica rows from inflating the reported phase work
            rows = len(prompts)
            # revocations / skipped_forwards are whole-batch totals like
            # forwards: each real request gets its share
            req.stats = dataclasses.replace(
                stats,
                tokens_generated=self.dcfg.gen_length,
                forward_equivalents=stats.forward_equivalents / real,
                wall_time=stats.wall_time / real,
                revocations=stats.revocations / real,
                skipped_forwards=stats.skipped_forwards / real,
                phase_counts={k: v / rows
                              for k, v in stats.phase_counts.items()})
            req.finish_time = now
            self.done[req.rid] = req
        return [r.rid for r in batch]

    def run_until_idle(self) -> None:
        while self.queue:
            self.step()

    # -- metrics -----------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Aggregate serving metrics over finished requests.

        Throughput accounting counts REAL requests only: `done` never
        holds pad replicas, and the per-request stats summed here were
        pro-rated across real batch members in `step()`, so replicated
        rows (batches padded to `max_batch`) and mask pad columns inflate
        neither tokens nor forward-equivalents.
        """
        reqs = list(self.done.values())
        if not reqs:
            return {}
        lat = [r.latency for r in reqs]
        toks = sum(r.stats.tokens_generated for r in reqs)
        fwds = sum(r.stats.forward_equivalents for r in reqs)
        decode_s = sum(r.stats.wall_time for r in reqs)
        span = max(r.finish_time for r in reqs) - \
            min(r.submit_time for r in reqs)
        return {"requests": len(reqs),
                "mean_latency_s": float(np.mean(lat)),
                "p95_latency_s": float(np.percentile(lat, 95)),
                "throughput_tps": toks / max(span, 1e-9),
                "decode_tps": toks / max(decode_s, 1e-9),
                "forward_equivalents": float(fwds),
                "revocations": float(sum(r.stats.revocations
                                         for r in reqs)),
                "skipped_forwards": float(sum(r.stats.skipped_forwards
                                              for r in reqs))}
