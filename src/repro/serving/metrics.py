"""A real metrics registry for the serving stack (Prometheus text
exposition, format 0.0.4).

The seed's ``/metrics`` endpoint hand-concatenated f-strings inside the
server — no HELP/TYPE metadata, no shared escaping, nothing any other
layer could register into.  This module replaces that with three typed
instruments and one registry:

* ``Counter``   — monotone; ``inc(amount)``.
* ``Gauge``     — settable; ``set(v)`` / ``inc`` / ``dec``.
* ``Histogram`` — cumulative buckets (Prometheus convention: each
  ``le``-labelled bucket counts observations ≤ its bound, ``+Inf``
  always present) plus ``_sum``/``_count`` series.

All three are label-aware: ``metric.labels(model="tiny").inc()`` keys a
child per label-value tuple.  Everything is thread-safe under one
registry lock — scrapes happen on the server's event loop while decode
worker threads observe latencies, so atomicity here is load-bearing,
not hygiene.

Two publication paths:

* **registered instruments** — created via ``registry.counter(...)``
  etc.; the scheduler's latency/queue-depth/tokens histograms and the
  per-strategy decode counters live here.
* **collector callbacks** — ``registry.register_collector(fn)`` where
  ``fn() -> iterable[Family]`` snapshots state that already has an
  owner (router residency, scheduler counters, decode-cache info) at
  scrape time, instead of mirroring it into gauges it could drift from.

``render()`` emits ``# HELP``/``# TYPE`` per family and escapes label
values (backslash, quote, newline) and help text per the exposition
format; ``CONTENT_TYPE`` is the matching Content-Type header value.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

CONTENT_TYPE = "text/plain; version=0.0.4"

# Prometheus' default latency ladder: 5ms .. 10s
DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0)


def escape_label_value(value: str) -> str:
    """Exposition-format label escaping: backslash first (escaping the
    escapes), then quote and newline — one unescaped quote corrupts the
    whole scrape."""
    return (str(value).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def escape_help(text: str) -> str:
    return str(text).replace("\\", r"\\").replace("\n", r"\n")


def format_value(v) -> str:
    """Ints render bare (``repro_up 1``, what the tests grep for);
    floats use repr; non-finite values use the exposition spellings."""
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def format_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    pairs = [f'{k}="{escape_label_value(v)}"' for k, v in labels.items()]
    return "{" + ",".join(pairs) + "}"


@dataclasses.dataclass
class Family:
    """One metric family as a collector callback reports it: a name, a
    type, help text, and ``(labels, value)`` samples.  ``suffix`` lets a
    histogram-shaped collector emit ``_bucket``/``_sum``/``_count``
    series under one family (unused by plain counter/gauge families)."""

    name: str
    mtype: str                     # "counter" | "gauge" | "histogram"
    help: str
    samples: List[Tuple[Dict[str, str], float]]
    suffixes: Optional[List[Tuple[str, Dict[str, str], float]]] = None

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {escape_help(self.help)}",
                 f"# TYPE {self.name} {self.mtype}"]
        for labels, value in self.samples:
            lines.append(
                f"{self.name}{format_labels(labels)} {format_value(value)}")
        for suffix, labels, value in self.suffixes or ():
            lines.append(f"{self.name}{suffix}{format_labels(labels)} "
                         f"{format_value(value)}")
        return lines


class _Metric:
    """Shared label plumbing: a metric is a family; ``labels(**kv)``
    returns (creating on first use) the child for one label-value
    combination.  Unlabelled metrics have exactly one child, keyed ()."""

    mtype = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = (), *,
                 lock: Optional[threading.Lock] = None):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock or threading.Lock()
        self._children: Dict[Tuple[str, ...], object] = {}
        if not self.labelnames:
            self._children[()] = self._make_child()

    def _make_child(self):
        raise NotImplementedError

    def labels(self, **kv):
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(kv)}, "
                f"declared {sorted(self.labelnames)}")
        key = tuple(str(kv[k]) for k in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make_child()
        return child

    def _label_dict(self, key: Tuple[str, ...]) -> Dict[str, str]:
        return dict(zip(self.labelnames, key))

    # default child for unlabelled convenience calls
    def _solo(self):
        if self.labelnames:
            raise ValueError(f"{self.name} is labelled; call "
                             f".labels(...) first")
        return self._children[()]

    def family(self) -> Family:
        raise NotImplementedError


class _CounterChild:
    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self.value += amount


class Counter(_Metric):
    mtype = "counter"

    def _make_child(self):
        return _CounterChild(self._lock)

    def inc(self, amount: float = 1.0) -> None:
        self._solo().inc(amount)

    def family(self) -> Family:
        with self._lock:
            samples = [(self._label_dict(k), c.value)
                       for k, c in self._children.items()]
        return Family(self.name, self.mtype, self.help, samples)


class _GaugeChild:
    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)


class Gauge(_Metric):
    mtype = "gauge"

    def _make_child(self):
        return _GaugeChild(self._lock)

    def set(self, v: float) -> None:
        self._solo().set(v)

    def inc(self, amount: float = 1.0) -> None:
        self._solo().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._solo().dec(amount)

    def family(self) -> Family:
        with self._lock:
            samples = [(self._label_dict(k), c.value)
                       for k, c in self._children.items()]
        return Family(self.name, self.mtype, self.help, samples)


class _HistogramChild:
    def __init__(self, bounds: Tuple[float, ...], lock: threading.Lock):
        self._lock = lock
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)   # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.sum += v
            self.count += 1
            for i, bound in enumerate(self.bounds):
                if v <= bound:
                    self.bucket_counts[i] += 1
                    break
            else:
                self.bucket_counts[-1] += 1


class Histogram(_Metric):
    mtype = "histogram"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = (), *,
                 buckets: Sequence[float] = DEFAULT_BUCKETS,
                 lock: Optional[threading.Lock] = None):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("a histogram needs at least one bucket")
        super().__init__(name, help, labelnames, lock=lock)

    def _make_child(self):
        return _HistogramChild(self.buckets, self._lock)

    def observe(self, v: float) -> None:
        self._solo().observe(v)

    def family(self) -> Family:
        suffixes: List[Tuple[str, Dict[str, str], float]] = []
        with self._lock:
            children = list(self._children.items())
            for key, child in children:
                base = self._label_dict(key)
                cumulative = 0
                for bound, n in zip(child.bounds, child.bucket_counts):
                    cumulative += n
                    suffixes.append(("_bucket",
                                     {**base,
                                      "le": format_value(float(bound))},
                                     cumulative))
                cumulative += child.bucket_counts[-1]
                suffixes.append(("_bucket", {**base, "le": "+Inf"},
                                 cumulative))
                suffixes.append(("_sum", dict(base), child.sum))
                suffixes.append(("_count", dict(base), child.count))
        return Family(self.name, self.mtype, self.help, [], suffixes)


class MetricsRegistry:
    """Instrument factory + scrape-time renderer.  One per server.

    Instruments are created once and cached by name (re-declaring with a
    different type or label set is an error — silent merging is how two
    call sites end up fighting over one series).  Collector callbacks
    run at every ``render()``, so scraped state is always a live
    snapshot, never a mirror that can lag."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}
        self._order: List[str] = []
        self._collectors: List[Callable[[], Iterable[Family]]] = []

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if type(existing) is not cls or \
                        existing.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} re-declared with a different "
                        f"type or label set")
                return existing
            metric = cls(name, help, labelnames, **kw)
            self._metrics[name] = metric
            self._order.append(name)
            return metric

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (), *,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def register_collector(
            self, fn: Callable[[], Iterable[Family]]) -> None:
        with self._lock:
            self._collectors.append(fn)

    def render(self) -> str:
        """The scrape body.  Collector families render first (they carry
        ``repro_up`` and the seed-era series the dashboards/tests pin),
        then registered instruments in declaration order."""
        with self._lock:
            collectors = list(self._collectors)
            metrics = [self._metrics[n] for n in self._order]
        lines: List[str] = []
        for fn in collectors:
            for family in fn():
                lines.extend(family.render())
        for metric in metrics:
            lines.extend(metric.family().render())
        return "\n".join(lines) + "\n"
