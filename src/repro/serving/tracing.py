"""Request tracing: per-request span records through the serving stack,
exported as Chrome trace-event JSON (``chrome://tracing`` / Perfetto).

The scheduler records a ``Span`` per lifecycle stage of every request —
``queue_wait`` (submit → batch selection), ``batch_assembly``
(selection + padding), one ``decode_block[i]`` per block-grain executor
dispatch, ``cache_refresh`` when the decode's cache policy re-captured
KV state, and ``emit`` (fan-out of the terminal event) — into a
``TraceStore``.  When the decode ran with ``trace=true`` the request's
``DecodeTrace`` (the on-device TraceBuffer read-back,
``core/tracebuffer.py``) is attached too, and the export interleaves
per-step counter events — ``commits`` (the FINAL commit histogram, so
the counter sums exactly to ``tokens_generated`` even under wino_r
revocation), ``revocations``, ``skipped``, and the FDM-A phase — across
the decode spans' wall-clock extent.

Export format is the Chrome trace-event JSON object form::

    {"traceEvents": [{"name", "cat", "ph": "X"|"C"|"M",
                      "ts": µs, "dur": µs, "pid", "tid", "args"}, ...],
     "displayTimeUnit": "ms"}

with one process per request (pid = rid) so several requests can be
merged into one viewer timeline.  ``GET /v1/trace/{rid}`` serves it;
``tools/trace_view.py`` renders it in a terminal.

Retention mirrors the scheduler's stream retention: traces of finished
requests are kept for the most recent ``retain`` requests, then dropped
FIFO — the scheduler calls ``retire`` from the same choke point that
retires streams and engine bookkeeping.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional

SCHED_TID = 0        # scheduler-lifecycle spans
DEVICE_TID = 1       # on-device step counters


@dataclasses.dataclass(frozen=True)
class Span:
    """One closed interval of a request's life, ``perf_counter`` based."""

    name: str
    cat: str
    start_s: float
    end_s: float
    args: Optional[Dict] = None

    @property
    def dur_s(self) -> float:
        return max(self.end_s - self.start_s, 0.0)


class SpanTimer:
    """``with store.span(rid, "name", "cat"):`` — record on exit, even
    when the body raises (a failed block dispatch is exactly the span
    you want to see in the trace)."""

    def __init__(self, store: "TraceStore", rids, name: str, cat: str,
                 args: Optional[Dict] = None):
        self.store = store
        self.rids = rids
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self.start_s = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        span = Span(self.name, self.cat, self.start_s,
                    time.perf_counter(), self.args)
        for rid in self.rids:
            self.store.add(rid, span)
        return False


class TraceStore:
    """Per-rid span lists + attached DecodeTraces, bounded FIFO.

    Thread-safe: spans are recorded from the scheduler's event loop AND
    its decode executor thread, while ``/v1/trace`` reads happen on the
    server loop."""

    def __init__(self, retain: int = 256):
        self.retain = max(retain, 1)
        self._lock = threading.Lock()
        self._spans: Dict[int, List[Span]] = {}
        self._traces: Dict[int, object] = {}     # rid -> DecodeTrace
        self._meta: Dict[int, Dict] = {}
        self._retired: Deque[int] = deque()

    def add(self, rid: int, span: Span) -> None:
        with self._lock:
            self._spans.setdefault(rid, []).append(span)

    def span(self, rids, name: str, cat: str = "serving",
             args: Optional[Dict] = None) -> SpanTimer:
        if isinstance(rids, int):
            rids = (rids,)
        return SpanTimer(self, rids, name, cat, args)

    def attach(self, request_id: int, decode_trace, **meta) -> None:
        """Attach the on-device trace (and wire metadata) on finish.
        ``meta`` keys are free-form (``rid=...`` included — hence the
        positional parameter's longer name)."""
        with self._lock:
            if decode_trace is not None:
                self._traces[request_id] = decode_trace
            self._meta.setdefault(request_id, {}).update(meta)

    def retire(self, rid: int) -> None:
        """The request reached its terminal event; keep its trace for
        the most recent ``retain`` finishers, drop the oldest beyond."""
        with self._lock:
            if rid not in self._spans and rid not in self._traces:
                return
            self._retired.append(rid)
            while len(self._retired) > self.retain:
                old = self._retired.popleft()
                self._spans.pop(old, None)
                self._traces.pop(old, None)
                self._meta.pop(old, None)

    def known(self, rid: int) -> bool:
        with self._lock:
            return rid in self._spans or rid in self._traces

    def chrome(self, rid: int) -> Dict:
        """Chrome trace-event JSON for one request.  ``KeyError`` for an
        unknown (or already-retired) rid."""
        with self._lock:
            if rid not in self._spans and rid not in self._traces:
                raise KeyError(rid)
            spans = list(self._spans.get(rid, ()))
            trace = self._traces.get(rid)
            meta = dict(self._meta.get(rid, ()))
        return chrome_trace(rid, spans, trace, meta)


def _us(t_s: float, t0_s: float) -> float:
    return round((t_s - t0_s) * 1e6, 1)


def chrome_trace(rid: int, spans: List[Span], decode_trace=None,
                 meta: Optional[Dict] = None) -> Dict:
    """Assemble the trace-event JSON (module docstring has the shape).

    Device step counters have no host timestamps (the whole point of the
    on-device TraceBuffer is that steps never sync), so the per-step
    counter events are laid out evenly across the wall-clock extent of
    the ``decode_block`` spans — honest about what is known (step order,
    block membership, per-step counts) without inventing per-step times.
    """
    t0 = min((s.start_s for s in spans), default=0.0)
    events: List[Dict] = [
        {"name": "process_name", "ph": "M", "pid": rid, "tid": SCHED_TID,
         "args": {"name": f"request {rid}"}},
        {"name": "thread_name", "ph": "M", "pid": rid, "tid": SCHED_TID,
         "args": {"name": "scheduler"}},
    ]
    decode_lo, decode_hi = None, None
    for span in sorted(spans, key=lambda s: s.start_s):
        events.append({
            "name": span.name, "cat": span.cat, "ph": "X",
            "ts": _us(span.start_s, t0),
            "dur": round(span.dur_s * 1e6, 1),
            "pid": rid, "tid": SCHED_TID,
            **({"args": span.args} if span.args else {})})
        if span.cat == "decode":
            decode_lo = span.start_s if decode_lo is None \
                else min(decode_lo, span.start_s)
            decode_hi = span.end_s if decode_hi is None \
                else max(decode_hi, span.end_s)

    if decode_trace is not None and decode_trace.steps:
        events.append({"name": "thread_name", "ph": "M", "pid": rid,
                       "tid": DEVICE_TID, "args": {"name": "device steps"}})
        steps = decode_trace.steps
        if decode_lo is None:
            decode_lo, decode_hi = t0, t0 + steps * 1e-6
        pitch = max((decode_hi - decode_lo) / steps, 1e-9)
        histogram = decode_trace.commit_histogram()
        for i in range(steps):
            ts = _us(decode_lo + i * pitch, t0)
            counters = {"commits": int(histogram[i]),
                        "revocations": int(decode_trace.revocations[i]),
                        "skipped": int(decode_trace.skipped[i])}
            events.append({"name": "commits", "cat": "device", "ph": "C",
                           "ts": ts, "pid": rid, "tid": DEVICE_TID,
                           "args": counters})
            args = {"step": i, "block": int(decode_trace.block[i]),
                    "raw_commits": int(decode_trace.commits[i])}
            if int(decode_trace.phase[i]) >= 0:
                args["phase"] = int(decode_trace.phase[i])
            events.append({"name": f"step {i}", "cat": "device",
                           "ph": "X", "ts": ts,
                           "dur": round(pitch * 1e6, 1),
                           "pid": rid, "tid": DEVICE_TID, "args": args})

    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta:
        out["otherData"] = meta
    return out
