"""Named-model routing over multiple ServingEngines, under an explicit
bytes-budget LRU.

Models are *registered* as factories (``name -> () -> ServingEngine``)
and *built* lazily on first use, so a router can know about many more
models than fit in memory.  ``engine(name)`` returns the resident engine,
building it if needed, marking it most-recently-used, and then enforcing
the budget: while the summed parameter bytes of resident engines exceed
``RouterConfig.budget_bytes``, the least-recently-used IDLE engine is
force-dropped.  A busy engine (queued work or mid-decode, as reported by
its busy probe) is never evicted — the budget transiently overshoots
instead and converges as decodes drain.

Eviction actually frees memory because of the PR-2/PR-3 cache design: the
router (plus at most a scheduler, which the ``on_evict`` hook tears down)
holds the only strong references to an engine, the engine holds the only
reference to its params, and the Decoder's process-wide runner cache only
*weakly* anchors those params — so dropping the slot lets the params
leaves collect, their ``weakref.finalize`` anchors fire, and the compiled
executables evict.  ``decode_cache_info().entries`` observably shrinks;
the router tests assert exactly that.

Hot swap = rebuild: ``hot_swap(name)`` (optionally with a new factory)
drops the resident engine and builds a fresh one from the factory.  New
params with the same pytree structure even reuse the old compilations'
jit wrappers' shapes — but the old entry is gone, so nothing pins the old
weights.
"""
from __future__ import annotations

import gc
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from repro.configs.base import RouterConfig
from repro.serving.engine import ServingEngine


def params_bytes(params) -> int:
    """Total bytes of a params pytree's array leaves."""
    return int(sum(leaf.nbytes for leaf in jax.tree.leaves(params)
                   if hasattr(leaf, "nbytes")))


@dataclass
class _Slot:
    engine: ServingEngine
    nbytes: int
    last_used: float = 0.0
    # busy probe: the router alone can only see queued work; a scheduler
    # wrapping the engine also knows about the batch in flight and
    # installs a probe covering both (ServingServer does this)
    busy: Optional[Callable[[], bool]] = field(default=None)

    def is_busy(self) -> bool:
        if self.busy is not None:
            return bool(self.busy())
        return self.engine.queue_depth > 0


class ModelRouter:
    def __init__(self, rcfg: RouterConfig = RouterConfig(), *,
                 on_evict: Optional[Callable] = None):
        self.rcfg = rcfg
        self.on_evict = on_evict          # (name, engine) -> None
        self._factories: "OrderedDict[str, Callable[[], ServingEngine]]" \
            = OrderedDict()
        self._slots: "OrderedDict[str, _Slot]" = OrderedDict()
        self.counters = {"builds": 0, "evictions": 0, "swaps": 0,
                         "rebuilds": 0}

    # -- registration ------------------------------------------------------
    def register(self, name: str,
                 factory: Callable[[], ServingEngine]) -> None:
        if self.rcfg.max_models and \
                len(self._factories) >= self.rcfg.max_models and \
                name not in self._factories:
            raise ValueError(
                f"router is capped at {self.rcfg.max_models} models")
        self._factories[name] = factory

    def names(self) -> List[str]:
        return list(self._factories)

    @property
    def default(self) -> str:
        if not self._factories:
            raise RuntimeError("no models registered")
        return next(iter(self._factories))

    # -- routing -----------------------------------------------------------
    def engine(self, name: str) -> ServingEngine:
        """Resident engine for ``name`` (built on demand), LRU-touched;
        enforces the bytes budget on the way out."""
        if name not in self._factories:
            raise KeyError(f"unknown model {name!r}; have {self.names()}")
        slot = self._slots.get(name)
        if slot is None:
            engine = self._factories[name]()
            slot = _Slot(engine=engine, nbytes=params_bytes(engine.params))
            self._slots[name] = slot
            self.counters["builds"] += 1
        self._slots.move_to_end(name)
        slot.last_used = time.monotonic()
        self._enforce_budget(keep=name)
        return slot.engine

    def touch(self, name: str) -> Optional[ServingEngine]:
        """LRU-touch an ALREADY-RESIDENT engine and return it; None when
        not resident (or unknown).  Unlike ``engine()`` this never
        builds and never enforces the budget — residency only changes
        on builds — so it is the cheap fast path the server uses for
        warm models without hopping to an executor thread."""
        slot = self._slots.get(name)
        if slot is None:
            return None
        self._slots.move_to_end(name)
        slot.last_used = time.monotonic()
        return slot.engine

    def set_busy_probe(self, name: str,
                       probe: Optional[Callable[[], bool]]) -> None:
        slot = self._slots.get(name)
        if slot is not None:
            slot.busy = probe

    def resident(self, name: str) -> bool:
        return name in self._slots

    # -- eviction ----------------------------------------------------------
    def evict(self, name: str, force: bool = False) -> bool:
        """Drop a resident engine (its runner-cache entries evict with
        it).  Busy engines are refused unless ``force=True``."""
        slot = self._slots.get(name)
        if slot is None:
            return False
        if slot.is_busy() and not force:
            return False
        del self._slots[name]
        if self.on_evict is not None:
            self.on_evict(name, slot.engine)
        self.counters["evictions"] += 1
        del slot
        # drop the last strong refs NOW so the weak runner cache's
        # finalizers fire deterministically (stray reference cycles would
        # otherwise defer them to an arbitrary later collection)
        gc.collect()
        return True

    def _enforce_budget(self, keep: Optional[str] = None) -> None:
        budget = self.rcfg.budget_bytes
        if not budget:
            return
        # oldest-first scan; the engine just touched is exempt (evicting
        # what we are about to hand out would be self-defeating)
        for name in list(self._slots):
            if self.resident_bytes() <= budget:
                return
            if name != keep:
                self.evict(name)

    def resident_bytes(self) -> int:
        return sum(s.nbytes for s in self._slots.values())

    # -- hot swap ----------------------------------------------------------
    def hot_swap(self, name: str,
                 factory: Optional[Callable[[], ServingEngine]] = None
                 ) -> ServingEngine:
        """Replace a model's weights: optionally install a new factory,
        force-drop the resident engine (queued requests on it are lost —
        drain its scheduler first for a graceful swap), and build the
        replacement.  The old engine's params and compiled runners free
        with it."""
        if name not in self._factories:
            raise KeyError(f"unknown model {name!r}; have {self.names()}")
        if factory is not None:
            self._factories[name] = factory
        self.evict(name, force=True)
        self.counters["swaps"] += 1
        return self.engine(name)

    def rebuild(self, name: str) -> ServingEngine:
        """Supervision-triggered hot swap (the circuit breaker tripped):
        same mechanics as ``hot_swap`` with the existing factory —
        force-drop, fresh build — but counted separately, because swaps
        are operator intent and rebuilds are the engine crashing."""
        engine = self.hot_swap(name)
        self.counters["rebuilds"] += 1
        return engine

    # -- introspection -----------------------------------------------------
    def info(self) -> Dict:
        return {"budget_bytes": self.rcfg.budget_bytes,
                "resident_bytes": self.resident_bytes(),
                **self.counters,
                "models": {name: {
                    "resident": name in self._slots,
                    "bytes": (self._slots[name].nbytes
                              if name in self._slots else 0),
                    "queued": (self._slots[name].engine.queue_depth
                               if name in self._slots else 0),
                    "busy": (self._slots[name].is_busy()
                             if name in self._slots else False),
                } for name in self._factories}}
