"""Supervision primitives for the async serving loop.

The scheduler (``repro.serving.scheduler``) owns the control flow; this
module owns the *policy* pieces, each independently testable:

* ``Backoff``        — capped exponential retry delays, seeded jitter.
* ``CircuitBreaker`` — sliding-window engine-crash counter; trips after
  ``threshold`` engine-fatal failures inside ``window_s``.  While
  tripped (until the next clean batch) the model reports ``degraded``
  on ``/healthz``.
* ``DegradationLadder`` — the cheapen-before-shed admission policy:
  maps queue-depth / deadline-headroom pressure to a rung that scales a
  request's effective step budget down (never below one step per
  block).  Rung 0 is full quality; the 429 cliff only applies past the
  top rung's capacity.
* ``WatchdogTimeout`` — raised when one block exceeds the per-block
  watchdog; classified engine-fatal (a wedged forward can't be
  distinguished from a wedged engine, and the abandoned executor thread
  can't be preempted — only not resumed).

The supervision state machine, end to end (see DESIGN.md "Failure
model"):

    decode attempt ──ok──────────────────────────▶ done events, breaker reset
        │ transient failure (InjectedFault, CorruptOutputError, ...)
        ▼
    retry with backoff (≤ max_retries) ──ok──▶ done events
        │ still failing
        ▼
    batch size 1?  ──yes──▶ QUARANTINE: terminal `error` event
        │ no
        ▼
    bisect: re-queue both halves in fresh cohorts (they cannot re-merge)

    engine-fatal failure (OOM-shaped, WatchdogTimeout)
        ▼
    breaker.record_fault() ──tripped──▶ rebuild engine via router hot-swap
        ▼
    re-queue the batch's requests (per-request retry cap → `error`)
"""
from __future__ import annotations

import random
import time
from collections import deque
from typing import Deque, List, Optional

from repro.configs.base import DecodeConfig, DegradeConfig
from repro.serving.faults import backoff_delay, is_engine_fatal


class WatchdogTimeout(RuntimeError):
    """One block's decode exceeded the per-block watchdog budget."""


class Backoff:
    """Capped exponential backoff with deterministic, seeded jitter."""

    def __init__(self, base_s: float, cap_s: float, seed: int = 0):
        self.base_s = base_s
        self.cap_s = cap_s
        self._rand = random.Random(seed)

    def delay(self, attempt: int) -> float:
        return backoff_delay(attempt, self.base_s, self.cap_s, self._rand)


class CircuitBreaker:
    """Sliding-window crash counter over engine-fatal failures.

    ``record_fault()`` returns True exactly when the breaker trips
    (``threshold`` faults inside ``window_s``); the caller reacts by
    rebuilding the engine.  ``degraded`` stays True from the trip until
    ``record_success()`` (the first clean batch on the rebuilt engine),
    which is what ``/healthz`` surfaces.
    """

    def __init__(self, threshold: int, window_s: float):
        self.threshold = max(threshold, 1)
        self.window_s = window_s
        self._faults: Deque[float] = deque()
        self.trips = 0
        self.degraded = False

    def record_fault(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        self._faults.append(now)
        while self._faults and now - self._faults[0] > self.window_s:
            self._faults.popleft()
        if len(self._faults) >= self.threshold:
            self._faults.clear()
            self.trips += 1
            self.degraded = True
            return True
        return False

    def record_success(self) -> None:
        self.degraded = False

    @property
    def pending_faults(self) -> int:
        return len(self._faults)


class DegradationLadder:
    """Maps admission-time pressure to a degradation rung.

    Pressure inputs: queue depth as a fraction of ``max_queue_depth``
    (the primary signal — every rung names the depth fraction at which
    it engages) and deadline headroom (a request whose deadline is
    shorter than the expected queue wait, ``depth x recent batch EMA``,
    is bumped one extra rung: decoding it cheaper is strictly better
    than letting it expire in the queue).
    """

    def __init__(self, dgcfg: DegradeConfig, max_queue_depth: int):
        self.dgcfg = dgcfg
        self.max_queue_depth = max(max_queue_depth, 1)
        # rungs sorted shallow → deep so rung index == count engaged
        self.rungs = tuple(sorted(dgcfg.rungs, key=lambda r: r.at_depth))

    def rung_for(self, queue_depth: int,
                 deadline_s: Optional[float] = None,
                 batch_ema_s: float = 0.0) -> int:
        """0 = full quality; i > 0 = ``rungs[i-1]`` engaged."""
        if not self.dgcfg.enabled or not self.rungs:
            return 0
        frac = queue_depth / self.max_queue_depth
        rung = sum(1 for r in self.rungs if frac >= r.at_depth)
        if deadline_s and batch_ema_s > 0 and \
                queue_depth * batch_ema_s > deadline_s:
            rung += 1
        return min(rung, len(self.rungs))

    def cheapen_steps(self, rung: int, dcfg: DecodeConfig,
                      steps: Optional[int], gen_length: Optional[int],
                      block_size: Optional[int]) -> Optional[int]:
        """The effective ``steps`` override for this rung (None = leave
        the request's own value).  Scales the requested (or default)
        budget by the rung's ``steps_scale``, floored at one step per
        block so the geometry stays feasible.  Infeasible geometry is
        left untouched — the engine's submission-boundary validation
        owns that error."""
        if rung <= 0:
            return steps
        gen = gen_length if gen_length is not None else dcfg.gen_length
        bs = block_size if block_size is not None else dcfg.block_size
        base = steps if steps is not None else dcfg.steps
        if bs < 1 or gen < 1 or gen % bs or base < 1:
            return steps
        num_blocks = gen // bs
        scaled = max(num_blocks,
                     int(base * self.rungs[rung - 1].steps_scale))
        return min(scaled, base)


def classify_failure(exc: BaseException) -> str:
    """``"fatal"`` (engine suspect: rebuild territory) or
    ``"transient"`` (batch-local: retry → bisect territory)."""
    if isinstance(exc, WatchdogTimeout) or is_engine_fatal(exc):
        return "fatal"
    return "transient"


def bisect(requests: List) -> List[List]:
    """Split a failing batch's requests for re-queueing.  Both halves
    get fresh cohort ids downstream, so they can never re-form the
    failing batch; repeated failures shrink the poison request's cohort
    until it is alone and quarantined."""
    mid = max(len(requests) // 2, 1)
    halves = [requests[:mid]]
    if requests[mid:]:
        halves.append(requests[mid:])
    return halves
