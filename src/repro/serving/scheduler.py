"""Async continuous-batching scheduler: the loop between the HTTP front
end and the batch-synchronous ``ServingEngine``.

One ``AsyncScheduler`` owns one engine (one model's weights) and runs a
single worker task that repeatedly: reaps expired requests, selects the
next batch (``engine.select_batch`` — oldest-bucket-first, per-request
DecodeConfig respected), and drives it ONE BLOCK AT A TIME through
``engine.decode_batch_blocks`` on a worker thread
(``loop.run_in_executor``).  Diffusion decode is batch-synchronous, so the
block boundary is the scheduling grain: between blocks the event loop is
live — it admits new submissions into the queue, answers ``/healthz``,
fans freshly committed blocks out to per-request event streams, and
serves earlier requests' SSE reads — while the device crunches the next
block.  Admission into a *running* batch is impossible by construction
(every row advances through the same denoising steps), which is why
admission control lives at the queue: depth-bounded (``QueueFullError`` →
HTTP 429) and deadline-bounded (queued longer than the deadline → dropped
un-decoded with a terminal ``expired`` event).

Event streams: every request gets an ordered in-memory event log —
``block`` events as blocks commit (already sliced per request, replica
rows dropped, offsets rebased to the request's own coordinates) and ONE
terminal event (``done`` / ``cancelled`` / ``expired`` / ``shutdown``,
marked ``"final": true``).  ``events(rid)`` replays the log then follows
it live, so an SSE reader may attach before, during, or after the decode
and still see every event exactly once, in commit order.  Finished logs
are retained for ``stream_retain`` requests, then dropped FIFO.

Threading contract: all queue mutation (submit / cancel / select) happens
on the event-loop thread; ONLY the block-grain ``next()`` resumptions run
on the executor thread.  The engine itself is never touched from two
threads at once.
"""
from __future__ import annotations

import asyncio
from collections import deque
from typing import AsyncIterator, Deque, Dict, List, Optional

import numpy as np

from repro.core.decoder import SampleStats
from repro.serving.engine import Request, ServingEngine


class QueueFullError(RuntimeError):
    """Admission control: the engine queue is at max depth (HTTP 429)."""


def stats_dict(stats: Optional[SampleStats]) -> Dict:
    """A SampleStats as a JSON-serializable dict (wire format)."""
    if stats is None:
        return {}
    return {"steps": stats.steps,
            "forward_equivalents": stats.forward_equivalents,
            "wall_time_s": stats.wall_time,
            "tokens_generated": stats.tokens_generated,
            "tps": stats.tps,
            "revocations": stats.revocations,
            "skipped_forwards": stats.skipped_forwards,
            "phase_counts": stats.phase_counts}


class _Stream:
    """Ordered event log + wakeup for any number of async readers."""

    def __init__(self):
        self.events: List[Dict] = []
        self.new = asyncio.Event()

    def emit(self, event: Dict) -> None:
        self.events.append(event)
        self.new.set()

    @property
    def finished(self) -> bool:
        return bool(self.events) and self.events[-1].get("final", False)


class AsyncScheduler:
    """See the module docstring.  Construct, then ``await start()``."""

    def __init__(self, engine: ServingEngine, *,
                 max_queue_depth: int = 64,
                 default_deadline_s: float = 0.0,
                 stream_retain: int = 256):
        self.engine = engine
        self.max_queue_depth = max_queue_depth
        self.default_deadline_s = default_deadline_s
        self.stream_retain = max(stream_retain, 1)
        self._streams: Dict[int, _Stream] = {}
        self._retired: Deque[int] = deque()
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._closed = False
        self._decoding = False
        self.counters = {"submitted": 0, "finished": 0, "rejected": 0,
                         "cancelled": 0, "expired": 0, "errors": 0,
                         "batches": 0, "blocks": 0}

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> "AsyncScheduler":
        if self._task is None:
            self._loop = asyncio.get_running_loop()
            self._task = asyncio.create_task(self._run())
        return self

    async def close(self) -> None:
        """Finish the in-flight batch (if any), stop the worker, and end
        every still-open stream with a terminal ``shutdown`` event."""
        self.shutdown_nowait()
        if self._task is not None:
            await self._task
            self._task = None

    def shutdown_nowait(self) -> None:
        """Synchronous shutdown request (the router's eviction hook runs
        in sync context — possibly on a worker thread when the server
        builds engines off-loop): the worker exits after the batch it is
        on, and open streams get their terminal event.  Thread-safe: the
        asyncio primitives are only touched from the scheduler's own
        loop."""
        if self._loop is not None:
            try:
                on_loop = asyncio.get_running_loop() is self._loop
            except RuntimeError:
                on_loop = False
            if not on_loop:
                self._loop.call_soon_threadsafe(self.shutdown_nowait)
                return
        if self._closed:
            return
        self._closed = True
        self._wake.set()
        for rid, stream in self._streams.items():
            if not stream.finished:
                stream.emit({"type": "shutdown", "rid": rid,
                             "status": "shutdown", "final": True})

    @property
    def idle(self) -> bool:
        """No queued work and no batch in flight — safe to evict."""
        return not self._decoding and self.engine.queue_depth == 0

    # -- client API (event-loop thread only) -------------------------------
    def submit(self, prompt: np.ndarray, *,
               strategy: Optional[str] = None,
               steps: Optional[int] = None,
               gen_length: Optional[int] = None,
               block_size: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Admit a request; returns its rid.  Raises ``QueueFullError``
        at max queue depth, ``KeyError`` on an unknown strategy and
        ``ValueError`` on infeasible geometry (both from
        ``engine.submit``'s boundary validation)."""
        if self._closed:
            raise RuntimeError("scheduler is shut down")
        if self.engine.queue_depth >= self.max_queue_depth:
            self.counters["rejected"] += 1
            raise QueueFullError(
                f"queue at max depth {self.max_queue_depth}; retry later")
        if not deadline_s:
            # explicit 0 follows the ServerConfig convention (0 = no
            # deadline), same as omitting it; the engine-level API keeps
            # raw semantics (deadline_s=0.0 there = already expired)
            deadline_s = self.default_deadline_s \
                if self.default_deadline_s > 0 else None
        rid = self.engine.submit(prompt, strategy=strategy, steps=steps,
                                 gen_length=gen_length,
                                 block_size=block_size,
                                 deadline_s=deadline_s)
        self._streams[rid] = _Stream()
        self.counters["submitted"] += 1
        self._wake.set()
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a still-queued request (terminal ``cancelled`` event on
        its stream).  False once decoding started or after it finished."""
        ok = self.engine.cancel(rid)
        if ok:
            self.counters["cancelled"] += 1
            self._emit(rid, {"type": "cancelled", "rid": rid,
                             "status": "cancelled", "final": True})
        return ok

    async def events(self, rid: int) -> AsyncIterator[Dict]:
        """Replay-then-follow the request's event stream; the iterator
        ends after the terminal (``"final": true``) event.  Raises
        ``KeyError`` for an unknown (or already-retired) rid."""
        stream = self._streams[rid]
        i = 0
        while True:
            while i >= len(stream.events):
                stream.new.clear()
                await stream.new.wait()
            event = stream.events[i]
            i += 1
            yield event
            if event.get("final"):
                return

    async def result(self, rid: int) -> Dict:
        """Wait for and return the request's terminal event."""
        async for event in self.events(rid):
            if event.get("final"):
                return event
        raise RuntimeError(f"stream {rid} ended without a terminal event")

    def metrics(self) -> Dict:
        return {"queue_depth": self.engine.queue_depth,
                "decoding": self._decoding,
                "open_streams": len(self._streams),
                **self.counters,
                "engine": self.engine.summary()}

    # -- internals ---------------------------------------------------------
    def _emit(self, rid: int, event: Dict) -> None:
        stream = self._streams.get(rid)
        if stream is None:
            return
        if stream.finished:
            # exactly ONE terminal event per stream: a shutdown that
            # raced an in-flight batch must not be followed by that
            # batch's late `done` (nor double-retire the stream)
            return
        stream.emit(event)
        if event.get("final"):
            self._retired.append(rid)
            while len(self._retired) > self.stream_retain:
                old = self._retired.popleft()
                self._streams.pop(old, None)
                # the engine-side Request (result array included) retires
                # with its stream — without this, a long-running server
                # leaks one finished Request per request forever and
                # summary() scans an ever-growing history per scrape
                self.engine.done.pop(old, None)

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while not self._closed:
            for req in self.engine.reap_expired():
                self.counters["expired"] += 1
                self._emit(req.rid, {"type": "expired", "rid": req.rid,
                                     "status": "expired", "final": True})
            # busy BEFORE popping the queue: the router's idle probe may
            # run (from an executor thread) in the instant between
            # select_batch emptying the queue and the decode starting —
            # it must not see that window as evictable idleness
            self._decoding = True
            batch = self.engine.select_batch()
            if batch is None:
                self._decoding = False
                self._wake.clear()
                # re-check before sleeping: a submit may have landed
                # between select_batch and clear (same thread, so only if
                # select awaited — it doesn't — but cheap paranoia)
                if self.engine.queue_depth == 0 and not self._closed:
                    await self._wake.wait()
                continue
            self.counters["batches"] += 1
            try:
                blocks = self.engine.decode_batch_blocks(batch)
                while True:
                    kind, payload = await loop.run_in_executor(
                        None, _drive, blocks)
                    if kind == "done":
                        break
                    blk, lo, hi, tokens = payload
                    self.counters["blocks"] += 1
                    for i, req in enumerate(batch.requests):
                        # rebase to the request's own coordinates (mask
                        # pad columns sit left of its prompt)
                        self._emit(req.rid, {
                            "type": "block", "rid": req.rid, "block": blk,
                            "lo": lo - req.pad_cols,
                            "hi": hi - req.pad_cols,
                            "tokens": tokens[i].tolist()})
                for req in batch.requests:
                    self.counters["finished"] += 1
                    self._emit(req.rid, self._done_event(req))
            except Exception as e:
                # a failed batch must not kill the serving loop: its
                # requests get a terminal error event, everyone queued
                # behind it still gets served
                self.counters["errors"] += 1
                for req in batch.requests:
                    self._emit(req.rid, {
                        "type": "error", "rid": req.rid,
                        "status": "error", "final": True,
                        "error": f"{type(e).__name__}: {e}"})
            finally:
                self._decoding = False

    @staticmethod
    def _done_event(req: Request) -> Dict:
        return {"type": "done", "rid": req.rid, "status": "ok",
                "final": True,
                "tokens": req.result.tolist(),
                "latency_s": req.latency,
                "stats": stats_dict(req.stats)}


def _drive(blocks):
    """One generator resumption, shaped for run_in_executor."""
    try:
        return ("block", next(blocks))
    except StopIteration as fin:
        return ("done", fin.value)
