"""Async continuous-batching scheduler: the loop between the HTTP front
end and the batch-synchronous ``ServingEngine``.

One ``AsyncScheduler`` owns one engine (one model's weights) and runs a
single worker task that repeatedly: reaps expired requests, selects the
next batch (``engine.select_batch`` — oldest-bucket-first, per-request
DecodeConfig respected), and drives it ONE BLOCK AT A TIME through
``engine.decode_batch_blocks`` on a worker thread
(``loop.run_in_executor``).  Diffusion decode is batch-synchronous, so the
block boundary is the scheduling grain: between blocks the event loop is
live — it admits new submissions into the queue, answers ``/healthz``,
fans freshly committed blocks out to per-request event streams, and
serves earlier requests' SSE reads — while the device crunches the next
block.  Admission into a *running* batch is impossible by construction
(every row advances through the same denoising steps), which is why
admission control lives at the queue: depth-bounded (``QueueFullError`` →
HTTP 429) and deadline-bounded (queued longer than the deadline → dropped
un-decoded with a terminal ``expired`` event).

Supervision (PR 6): every batch runs under the ``SupervisorConfig``
policy.  A per-block watchdog bounds each decode resumption; decode
failures are caught at the batch boundary and classified
(``supervisor.classify_failure``): transient ones are retried in place
with capped exponential backoff, persistent ones are bisected — the
batch's halves re-queued under fresh cohort ids until the poison request
is isolated and quarantined with a single terminal ``error`` event, its
co-batched neighbours re-queued and served normally.  Engine-fatal
failures (OOM-shaped, watchdog) feed a sliding-window ``CircuitBreaker``;
on trip the engine is rebuilt through the router's hot-swap path
(``rebuild_engine`` callable, installed by ``ServingServer``) and
``health`` reports ``degraded`` until the next clean batch.  If a failed
attempt had already streamed block events, its streams get a non-final
``reset`` event telling readers to discard them (the retry re-decodes
from scratch, so results stay bit-identical to a fault-free run).

Admission additionally runs the ``DegradationLadder``: under queue-depth
or deadline-headroom pressure a request's effective step budget is
progressively cheapened (fewer steps = more parallel commits per step)
BEFORE the 429 cliff — shed steps before shedding requests.

Event streams: every request gets an ordered in-memory event log —
``block`` events as blocks commit (already sliced per request, replica
rows dropped, offsets rebased to the request's own coordinates), possibly
``reset`` events after a failed attempt, and ONE terminal event
(``done`` / ``cancelled`` / ``expired`` / ``error`` / ``shutdown``,
marked ``"final": true``).  ``events(rid)`` replays the log then follows
it live, so an SSE reader may attach before, during, or after the decode
and still see every event exactly once, in commit order.  Finished logs
are retained for ``stream_retain`` requests, then dropped FIFO.

Graceful drain: ``drain(deadline_s)`` stops admission (submits raise
``SchedulerDrainingError`` → HTTP 503), lets the backlog finish within
the deadline, then stops the worker — the in-flight batch completes its
current block, whatever remains gets a terminal ``shutdown`` event.

Threading contract: all queue mutation (submit / cancel / select /
requeue) happens on the event-loop thread; ONLY the block-grain
``next()`` resumptions run on the executor thread.  The engine itself is
never touched from two threads at once (a watchdog-abandoned resumption
finishes its current block in the background and its generator is never
resumed again).
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from typing import AsyncIterator, Callable, Deque, Dict, List, Optional

import numpy as np

from repro.configs.base import DegradeConfig, SupervisorConfig
from repro.core.decoder import SampleStats
from repro.serving.engine import Batch, Request, ServingEngine
from repro.serving.metrics import MetricsRegistry
from repro.serving.supervisor import (Backoff, CircuitBreaker,
                                      DegradationLadder, WatchdogTimeout,
                                      bisect, classify_failure)
from repro.serving.tracing import Span, TraceStore


class QueueFullError(RuntimeError):
    """Admission control: the engine queue is at max depth (HTTP 429)."""


class SchedulerDrainingError(RuntimeError):
    """Admission stopped: the scheduler is draining for shutdown
    (HTTP 503 + Retry-After — retryable against a replacement)."""


def stats_dict(stats: Optional[SampleStats]) -> Dict:
    """A SampleStats as a JSON-serializable dict (wire format) —
    ``SampleStats.as_dict()``, the one stable stats shape shared with
    ``ServingEngine.summary()`` and the benchmarks."""
    if stats is None:
        return {}
    return stats.as_dict()


class _Stream:
    """Ordered event log + wakeup for any number of async readers."""

    def __init__(self):
        self.events: List[Dict] = []
        self.new = asyncio.Event()

    def emit(self, event: Dict) -> None:
        self.events.append(event)
        self.new.set()

    @property
    def finished(self) -> bool:
        return bool(self.events) and self.events[-1].get("final", False)


class _AbandonBatch(Exception):
    """Drain deadline passed mid-batch: stop at this block boundary."""


class AsyncScheduler:
    """See the module docstring.  Construct, then ``await start()``."""

    def __init__(self, engine: ServingEngine, *,
                 max_queue_depth: int = 64,
                 default_deadline_s: float = 0.0,
                 stream_retain: int = 256,
                 svcfg: SupervisorConfig = SupervisorConfig(),
                 dgcfg: DegradeConfig = DegradeConfig(),
                 rebuild_engine: Optional[
                     Callable[[], ServingEngine]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 model: str = "",
                 profile_dir: str = ""):
        self.engine = engine
        self.max_queue_depth = max_queue_depth
        self.default_deadline_s = default_deadline_s
        self.stream_retain = max(stream_retain, 1)
        self.svcfg = svcfg
        self.dgcfg = dgcfg
        self.rebuild_engine = rebuild_engine
        self.model = model
        self.profile_dir = profile_dir
        # request tracing: span records at every lifecycle stage, same
        # retention horizon as the event streams (retired together)
        self.trace_store = TraceStore(retain=self.stream_retain)
        self._install_refresh_hook(engine)
        # metrics registry (optional — standalone schedulers skip it):
        # the scheduler owns the per-request distributions the flat
        # counters cannot express
        self._m_latency = self._m_queue_wait = None
        self._m_tokens = self._m_depth = self._m_decodes = None
        if registry is not None:
            self._m_latency = registry.histogram(
                "repro_request_latency_seconds",
                "End-to-end latency, submit to terminal event",
                ("model",))
            self._m_queue_wait = registry.histogram(
                "repro_queue_wait_seconds",
                "Time a request spent queued before batch selection",
                ("model",))
            self._m_depth = registry.histogram(
                "repro_queue_depth_at_submit",
                "Queue depth observed by each arriving request",
                ("model",), buckets=(0, 1, 2, 4, 8, 16, 32, 64, 128))
            self._m_tokens = registry.histogram(
                "repro_tokens_per_request",
                "Generated tokens per finished request",
                ("model",), buckets=(8, 16, 32, 64, 128, 256, 512, 1024))
            self._m_decodes = registry.counter(
                "repro_decodes_total",
                "Finished decodes by strategy and cache policy",
                ("model", "strategy", "cache_policy"))
        self.breaker = CircuitBreaker(svcfg.breaker_threshold,
                                      svcfg.breaker_window_s)
        self.ladder = DegradationLadder(dgcfg, max_queue_depth)
        self._backoff = Backoff(svcfg.backoff_base_s, svcfg.backoff_cap_s)
        self._streams: Dict[int, _Stream] = {}
        self._retired: Deque[int] = deque()
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._closed = False
        self._draining = False
        self._abandon = False
        self._decoding = False
        self._inflight: set = set()
        self._batch_ema_s = 0.0
        self.counters = {"submitted": 0, "finished": 0, "rejected": 0,
                         "cancelled": 0, "expired": 0, "errors": 0,
                         "batches": 0, "blocks": 0,
                         # supervision
                         "retries": 0, "requeued": 0, "quarantined": 0,
                         "watchdog_timeouts": 0, "engine_faults": 0,
                         "engine_rebuilds": 0, "rebuild_failures": 0,
                         "resets": 0, "degraded": 0}

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> "AsyncScheduler":
        if self._task is None:
            self._loop = asyncio.get_running_loop()
            self._task = asyncio.create_task(self._run())
        return self

    async def close(self) -> None:
        """Finish the in-flight batch (if any), stop the worker, and end
        every still-open stream with a terminal event — the in-flight
        batch's requests get their REAL ``done`` events (its decode
        completes), only still-queued work gets ``shutdown``."""
        self.shutdown_nowait()
        # claim-then-act: take ownership of the worker handle BEFORE the
        # await so a concurrent close()/drain() sees None instead of
        # double-awaiting and then clobbering a restarted worker (ANA202)
        task, self._task = self._task, None
        if task is not None:
            await task

    async def drain(self, deadline_s: Optional[float] = None) -> None:
        """Graceful shutdown (the SIGTERM path): stop admission NOW,
        give the backlog up to ``deadline_s`` (default
        ``svcfg.drain_deadline_s``) to finish, then stop.  The in-flight
        batch finishes the block it is on; whatever is still unfinished
        at the deadline gets a terminal ``shutdown`` event."""
        if deadline_s is None:
            deadline_s = self.svcfg.drain_deadline_s
        self._draining = True
        loop = asyncio.get_running_loop()
        t_end = loop.time() + max(deadline_s, 0.0)
        while (self.engine.queue_depth or self._decoding) \
                and loop.time() < t_end:
            await asyncio.sleep(0.02)
        self.shutdown_nowait()
        # claim-then-act, same as close(): own the handle before awaiting
        task, self._task = self._task, None
        if task is not None:
            remaining = max(t_end - loop.time(), 0.05)
            try:
                await asyncio.wait_for(asyncio.shield(task), remaining)
            except asyncio.TimeoutError:
                # past the deadline: the worker stops at the next block
                # boundary instead of finishing the batch
                self._abandon = True
                await task

    def shutdown_nowait(self) -> None:
        """Synchronous shutdown request (the router's eviction hook runs
        in sync context — possibly on a worker thread when the server
        builds engines off-loop): the worker exits after the batch it is
        on, and open streams get their terminal event.  Streams of the
        IN-FLIGHT batch are skipped here — its decode completes and they
        get their real ``done`` events (see the shutdown-race regression
        test); anything the worker abandons is swept with ``shutdown``
        when the loop exits.  Thread-safe: the asyncio primitives are
        only touched from the scheduler's own loop."""
        if self._loop is not None:
            try:
                on_loop = asyncio.get_running_loop() is self._loop
            except RuntimeError:
                on_loop = False
            if not on_loop:
                self._loop.call_soon_threadsafe(self.shutdown_nowait)
                return
        if self._closed:
            return
        self._closed = True
        self._wake.set()
        # snapshot: _emit's retention trimming pops retired streams out
        # of _streams mid-iteration.  Routing through _emit (not raw
        # stream.emit) keeps the finished-guard — the single choke point
        # that proves "exactly one terminal event per stream" (ANA205)
        for rid in list(self._streams):
            if rid not in self._inflight:
                self._emit(rid, {"type": "shutdown", "rid": rid,
                                 "status": "shutdown", "final": True})

    @property
    def idle(self) -> bool:
        """No queued work and no batch in flight — safe to evict."""
        return not self._decoding and self.engine.queue_depth == 0

    @property
    def health(self) -> str:
        """``ok`` | ``degraded`` (breaker tripped, engine rebuilt, no
        clean batch yet) | ``draining`` | ``shutdown``."""
        if self._closed:
            return "shutdown"
        if self._draining:
            return "draining"
        if self.breaker.degraded:
            return "degraded"
        return "ok"

    # -- client API (event-loop thread only) -------------------------------
    def submit(self, prompt: np.ndarray, *,
               strategy: Optional[str] = None,
               steps: Optional[int] = None,
               gen_length: Optional[int] = None,
               block_size: Optional[int] = None,
               cache_policy: Optional[str] = None,
               trace: Optional[bool] = None,
               deadline_s: Optional[float] = None) -> int:
        """Admit a request; returns its rid.  Raises ``QueueFullError``
        at max queue depth, ``SchedulerDrainingError`` while draining,
        ``KeyError`` on an unknown strategy and ``ValueError`` on
        infeasible geometry or an unknown/unservable ``cache_policy``
        (all from ``engine.submit``'s boundary validation).  Under
        pressure the degradation ladder cheapens the request's effective
        step budget before the queue-full cliff."""
        if self._closed:
            raise RuntimeError("scheduler is shut down")
        if self._draining:
            raise SchedulerDrainingError(
                "scheduler is draining for shutdown; retry elsewhere")
        depth = self.engine.queue_depth
        if depth >= self.max_queue_depth:
            self.counters["rejected"] += 1
            raise QueueFullError(
                f"queue at max depth {self.max_queue_depth}; retry later")
        if not deadline_s:
            # explicit 0 follows the ServerConfig convention (0 = no
            # deadline), same as omitting it; the engine-level API keeps
            # raw semantics (deadline_s=0.0 there = already expired)
            deadline_s = self.default_deadline_s \
                if self.default_deadline_s > 0 else None
        rung = self.ladder.rung_for(depth, deadline_s, self._batch_ema_s)
        if rung:
            cheap = self.ladder.cheapen_steps(rung, self.engine.dcfg,
                                              steps, gen_length,
                                              block_size)
            if cheap != steps:
                steps = cheap
                self.counters["degraded"] += 1
        rid = self.engine.submit(prompt, strategy=strategy, steps=steps,
                                 gen_length=gen_length,
                                 block_size=block_size,
                                 cache_policy=cache_policy,
                                 trace=trace,
                                 deadline_s=deadline_s)
        self._streams[rid] = _Stream()
        self.counters["submitted"] += 1
        if self._m_depth is not None:
            self._m_depth.labels(model=self.model).observe(depth)
        self._wake.set()
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a still-queued request (terminal ``cancelled`` event on
        its stream).  False once decoding started or after it finished."""
        ok = self.engine.cancel(rid)
        if ok:
            self.counters["cancelled"] += 1
            self._emit(rid, {"type": "cancelled", "rid": rid,
                             "status": "cancelled", "final": True})
        return ok

    async def events(self, rid: int) -> AsyncIterator[Dict]:
        """Replay-then-follow the request's event stream; the iterator
        ends after the terminal (``"final": true``) event.  Raises
        ``KeyError`` for an unknown (or already-retired) rid."""
        stream = self._streams[rid]
        i = 0
        while True:
            while i >= len(stream.events):
                stream.new.clear()
                await stream.new.wait()
            event = stream.events[i]
            i += 1
            yield event
            if event.get("final"):
                return

    async def result(self, rid: int) -> Dict:
        """Wait for and return the request's terminal event."""
        async for event in self.events(rid):
            if event.get("final"):
                return event
        raise RuntimeError(f"stream {rid} ended without a terminal event")

    def trace(self, rid: int) -> Dict:
        """Chrome trace-event JSON for one request: the scheduler's span
        records (queue wait, batch assembly, per-block decode, cache
        refresh, emit) plus — when the request decoded with
        ``trace=true`` — the on-device per-step counters.  ``KeyError``
        for a rid never selected into a batch or already retired."""
        return self.trace_store.chrome(rid)

    def metrics(self) -> Dict:
        return {"queue_depth": self.engine.queue_depth,
                "decoding": self._decoding,
                "open_streams": len(self._streams),
                "health": self.health,
                "ladder_rung": self.ladder.rung_for(
                    self.engine.queue_depth),
                "breaker_trips": self.breaker.trips,
                **self.counters,
                "faults_injected":
                    dict(self.engine.fault_injector.counters)
                    if self.engine.fault_injector is not None else {},
                "engine": self.engine.summary()}

    # -- internals ---------------------------------------------------------
    def _install_refresh_hook(self, engine: ServingEngine) -> None:
        """KV-cache refreshes happen inside the decoder between blocks;
        the engine surfaces them through this hook so the trace shows
        refresh time separately from decode time."""
        engine.on_cache_refresh = self._on_cache_refresh

    def _on_cache_refresh(self, requests, blk: int, t0: float,
                          t1: float) -> None:
        span = Span(f"cache_refresh[{blk}]", "decode", t0, t1,
                    {"block": blk})
        for req in requests:
            self.trace_store.add(req.rid, span)

    def _emit(self, rid: int, event: Dict) -> None:
        stream = self._streams.get(rid)
        if stream is None:
            return
        if stream.finished:
            # exactly ONE terminal event per stream: a shutdown that
            # raced an in-flight batch must not be followed by that
            # batch's late `done` (nor double-retire the stream)
            return
        stream.emit(event)
        if event.get("final"):
            self._retired.append(rid)
            # the request's trace retires on the same horizon as its
            # stream — /v1/trace stays answerable as long as /v1/stream
            self.trace_store.retire(rid)
            while len(self._retired) > self.stream_retain:
                old = self._retired.popleft()
                self._streams.pop(old, None)
                # the engine-side Request (result array included) retires
                # with its stream — without this, a long-running server
                # leaks one finished Request per request forever and
                # summary() scans an ever-growing history per scrape
                self.engine.done.pop(old, None)

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while not self._closed:
                for req in self.engine.reap_expired():
                    self.counters["expired"] += 1
                    self._emit(req.rid,
                               {"type": "expired", "rid": req.rid,
                                "status": "expired", "final": True})
                # busy BEFORE popping the queue: the router's idle probe
                # may run (from an executor thread) in the instant
                # between select_batch emptying the queue and the decode
                # starting — it must not see that window as evictable
                # idleness
                self._decoding = True
                t_sel = time.perf_counter()
                batch = self.engine.select_batch()
                if batch is None:
                    self._decoding = False
                    self._wake.clear()
                    # re-check before sleeping: a submit may have landed
                    # between select_batch and clear (same thread, so
                    # only if select awaited — it doesn't — but cheap
                    # paranoia)
                    if self.engine.queue_depth == 0 and not self._closed:
                        await self._wake.wait()
                    continue
                self.counters["batches"] += 1
                t_asm = time.perf_counter()
                asm_args = {"batch_size": len(batch.requests),
                            "strategy": batch.dcfg.strategy,
                            "cache_policy": batch.dcfg.cache_policy}
                for req in batch.requests:
                    self.trace_store.add(req.rid, Span(
                        "queue_wait", "serving", req.submit_time, t_sel))
                    self.trace_store.add(req.rid, Span(
                        "batch_assembly", "serving", t_sel, t_asm,
                        asm_args))
                    if self._m_queue_wait is not None:
                        self._m_queue_wait.labels(model=self.model) \
                            .observe(t_sel - req.submit_time)
                t0 = loop.time()
                try:
                    await self._decode_supervised(loop, batch)
                except _AbandonBatch:
                    break           # drain deadline: swept below
                finally:
                    self._decoding = False
                    # in place, NOT `= set()`: shutdown_nowait reads this
                    # set from foreign threads; a rebind would let that
                    # reader hold the stale object across the swap
                    # (ANA201)
                    self._inflight.clear()
                dt = loop.time() - t0
                self._batch_ema_s = dt if not self._batch_ema_s \
                    else 0.8 * self._batch_ema_s + 0.2 * dt
        finally:
            self._decoding = False
            self._inflight.clear()
            # final sweep: whatever never reached a terminal event
            # (abandoned in-flight work, late re-queues) ends with
            # `shutdown` — no stream is left dangling
            for rid, stream in list(self._streams.items()):
                if not stream.finished:
                    self._emit(rid, {"type": "shutdown", "rid": rid,
                                     "status": "shutdown", "final": True})

    async def _decode_supervised(self, loop, batch: Batch) -> None:
        """One batch under the supervision policy (module docstring)."""
        svc = self.svcfg
        attempt = 0
        while True:
            self._inflight.clear()
            self._inflight.update(r.rid for r in batch.requests)
            progress = {"blocks": 0}
            try:
                profiling = self._start_profiler()
                try:
                    await self._drive_batch(loop, batch, progress)
                finally:
                    self._stop_profiler(profiling)
                self.breaker.record_success()
                for req in batch.requests:
                    self.counters["finished"] += 1
                    self._record_finished(req, batch)
                    self._emit(req.rid, self._done_event(req))
                return
            except _AbandonBatch:
                raise
            except Exception as e:
                if progress["blocks"]:
                    # blocks already fanned out this attempt are stale —
                    # the retry re-decodes from scratch
                    for req in batch.requests:
                        self.counters["resets"] += 1
                        self._emit(req.rid,
                                   {"type": "reset", "rid": req.rid})
                if classify_failure(e) == "fatal":
                    await self._engine_fault(loop, batch, e)
                    return
                attempt += 1
                if attempt <= svc.max_retries:
                    self.counters["retries"] += 1
                    await asyncio.sleep(self._backoff.delay(attempt))
                    continue
                if len(batch.requests) == 1:
                    # the poison request, isolated: exactly one terminal
                    # error event; nobody else was in this batch
                    req = batch.requests[0]
                    self.counters["errors"] += 1
                    self.counters["quarantined"] += 1
                    self.engine.record_failed(req)
                    self._emit(req.rid, {
                        "type": "error", "rid": req.rid,
                        "status": "error", "final": True,
                        "error": f"{type(e).__name__}: {e}"})
                    return
                # persistent multi-request failure: bisect.  Fresh
                # cohort ids per half keep the halves from re-merging
                # into the batch that just failed; the poison's cohort
                # keeps shrinking until it is alone
                for half in bisect(batch.requests):
                    self.engine.requeue(half, fresh_group=True)
                    self.counters["requeued"] += len(half)
                self._wake.set()
                return

    def _record_finished(self, req: Request, batch: Batch) -> None:
        """Per-request observability on decode success: latency/token
        histograms, the per-strategy decode counter, and the on-device
        DecodeTrace attached to the request's span record."""
        if self._m_decodes is not None:
            self._m_decodes.labels(
                model=self.model, strategy=batch.dcfg.strategy,
                cache_policy=batch.dcfg.cache_policy).inc()
            self._m_latency.labels(model=self.model).observe(req.latency)
            self._m_tokens.labels(model=self.model).observe(
                req.stats.tokens_generated if req.stats else 0)
        trace = req.stats.trace if req.stats is not None else None
        self.trace_store.attach(
            req.rid, trace, rid=req.rid,
            strategy=batch.dcfg.strategy,
            cache_policy=batch.dcfg.cache_policy,
            tokens_generated=int(req.stats.tokens_generated)
            if req.stats else 0)

    def _start_profiler(self) -> bool:
        """``ServerConfig.profile_dir`` (non-empty) brackets each decoded
        batch with a ``jax.profiler`` device trace — the heavyweight
        opt-in complement to the always-cheap span records."""
        if not self.profile_dir:
            return False
        import jax
        try:
            jax.profiler.start_trace(self.profile_dir)
            return True
        except Exception:
            # a profiler session may already be live (concurrent model,
            # external harness): tracing is telemetry, never a reason to
            # fail the decode
            return False

    def _stop_profiler(self, started: bool) -> None:
        if not started:
            return
        import jax
        try:
            jax.profiler.stop_trace()
        except Exception:
            pass

    async def _drive_batch(self, loop, batch: Batch, progress: Dict
                           ) -> None:
        """Drive one decode attempt block by block, under the watchdog;
        fans block events out to the per-request streams."""
        svc = self.svcfg
        rids = [r.rid for r in batch.requests]
        blocks = self.engine.decode_batch_blocks(batch)
        while True:
            t_blk = time.perf_counter()
            fut = loop.run_in_executor(None, _drive, blocks)
            if svc.watchdog_s > 0:
                try:
                    kind, payload = await asyncio.wait_for(
                        asyncio.shield(fut), svc.watchdog_s)
                except asyncio.TimeoutError:
                    # the resumption keeps running on its executor
                    # thread but is never resumed again; the engine may
                    # be wedged, so this is engine-fatal
                    fut.add_done_callback(_retrieve)
                    self.counters["watchdog_timeouts"] += 1
                    raise WatchdogTimeout(
                        f"block exceeded the {svc.watchdog_s:g}s "
                        f"watchdog") from None
            else:
                kind, payload = await fut
            if kind == "done":
                final = Span("decode_finish", "decode", t_blk,
                             time.perf_counter())
                for rid in rids:
                    self.trace_store.add(rid, final)
                return
            blk, lo, hi, tokens = payload
            span = Span(f"decode_block[{blk}]", "decode", t_blk,
                        time.perf_counter(), {"block": blk})
            for rid in rids:
                self.trace_store.add(rid, span)
            self.counters["blocks"] += 1
            progress["blocks"] += 1
            for i, req in enumerate(batch.requests):
                # rebase to the request's own coordinates (mask pad
                # columns sit left of its prompt)
                self._emit(req.rid, {
                    "type": "block", "rid": req.rid, "block": blk,
                    "lo": lo - req.pad_cols,
                    "hi": hi - req.pad_cols,
                    "tokens": tokens[i].tolist()})
            if self._abandon:
                raise _AbandonBatch()

    async def _engine_fault(self, loop, batch: Batch,
                            exc: Exception) -> None:
        """Engine-fatal failure: count it, maybe trip the breaker and
        rebuild the engine, re-queue the batch's requests (per-request
        retry cap → terminal error)."""
        self.counters["engine_faults"] += 1
        if self.breaker.record_fault() and self.rebuild_engine is not None:
            try:
                rebuilt = await loop.run_in_executor(
                    None, self.rebuild_engine)
            except Exception:
                self.counters["rebuild_failures"] += 1
                rebuilt = None
            if rebuilt is not None:
                rebuilt.adopt(self.engine)
                self.engine = rebuilt
                # hooks are NOT adopted — re-point the refresh spans at
                # the engine that will actually decode from here on
                self._install_refresh_hook(rebuilt)
                self.counters["engine_rebuilds"] += 1
        survivors = []
        for req in batch.requests:
            req.retries += 1
            if req.retries > self.svcfg.max_retries:
                self.counters["errors"] += 1
                self.engine.record_failed(req)
                self._emit(req.rid, {
                    "type": "error", "rid": req.rid,
                    "status": "error", "final": True,
                    "error": f"{type(exc).__name__}: {exc}"})
            else:
                survivors.append(req)
        if survivors:
            self.engine.requeue(survivors)
            self.counters["requeued"] += len(survivors)
            self._wake.set()

    def _done_event(self, req: Request) -> Dict:
        # the "emit" span covers payload construction (tolist dominates
        # fan-out cost) and lands BEFORE _emit, whose terminal event
        # retires the trace — nothing may attach after retirement
        with self.trace_store.span(req.rid, "emit", "serving"):
            return {"type": "done", "rid": req.rid, "status": "ok",
                    "final": True,
                    "tokens": req.result.tolist(),
                    "latency_s": req.latency,
                    "stats": stats_dict(req.stats)}


def _drive(blocks):
    """One generator resumption, shaped for run_in_executor."""
    try:
        return ("block", next(blocks))
    except StopIteration as fin:
        return ("done", fin.value)


def _retrieve(fut) -> None:
    """Mark an abandoned (watchdog-timed-out) future's eventual
    exception as retrieved so it can't warn at GC time."""
    if not fut.cancelled():
        fut.exception()
