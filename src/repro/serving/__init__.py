"""The serving stack, bottom-up:

* ``engine``    — batched, bucket-scheduled decoding over one model's
                  weights (synchronous; the batch-selection/decode split
                  the async layer builds on)
* ``scheduler`` — the async continuous-batching loop: admission control,
                  deadlines, per-request event streams at the block grain
* ``router``    — named-model routing over engines under a bytes-budget
                  LRU, with hot swap and observable cache eviction
* ``server``    — stdlib asyncio HTTP/1.1 + SSE front end over a router
* ``client``    — small blocking client (tests / examples / load gen)
"""
from repro.serving.client import ServerError, ServingClient
from repro.serving.engine import Batch, Request, ServingEngine
from repro.serving.router import ModelRouter, params_bytes
from repro.serving.scheduler import (AsyncScheduler, QueueFullError,
                                     stats_dict)
from repro.serving.server import ServerThread, ServingServer

__all__ = [
    "Request", "Batch", "ServingEngine",
    "AsyncScheduler", "QueueFullError", "stats_dict",
    "ModelRouter", "params_bytes",
    "ServingServer", "ServerThread",
    "ServingClient", "ServerError",
]
