"""The serving stack, bottom-up:

* ``engine``     — batched, bucket-scheduled decoding over one model's
                   weights (synchronous; the batch-selection/decode split
                   the async layer builds on)
* ``faults``     — deterministic fault injection at the engine's block
                   grain (scheduled + seeded-chaos failures; the
                   always-on output validator lives here too)
* ``supervisor`` — supervision policy pieces: retry backoff, circuit
                   breaker, the degradation ladder, failure
                   classification
* ``scheduler``  — the async continuous-batching loop: admission control
                   (depth / deadline / degradation ladder), per-request
                   event streams at the block grain, and batch
                   supervision (watchdog, retries, bisection quarantine,
                   engine rebuild, graceful drain)
* ``router``     — named-model routing over engines under a bytes-budget
                   LRU, with hot swap and observable cache eviction
* ``metrics``    — Prometheus-text metrics registry (counters, gauges,
                   histograms, collector callbacks) behind ``/metrics``
* ``tracing``    — per-request span records + Chrome trace-event export
                   behind ``/v1/trace/{rid}``
* ``server``     — stdlib asyncio HTTP/1.1 + SSE front end over a router
* ``client``     — small blocking client with backoff retries (tests /
                   examples / load gen)
"""
from repro.serving.client import ServerError, ServingClient
from repro.serving.engine import Batch, Request, ServingEngine
from repro.serving.faults import (CorruptOutputError, Fault,
                                  FaultInjector, InjectedFault,
                                  SimulatedOOM, is_engine_fatal)
from repro.serving.metrics import (CONTENT_TYPE, Counter, Family, Gauge,
                                   Histogram, MetricsRegistry)
from repro.serving.router import ModelRouter, params_bytes
from repro.serving.scheduler import (AsyncScheduler, QueueFullError,
                                     SchedulerDrainingError, stats_dict)
from repro.serving.server import ServerThread, ServingServer
from repro.serving.supervisor import (Backoff, CircuitBreaker,
                                      DegradationLadder, WatchdogTimeout)
from repro.serving.tracing import Span, TraceStore, chrome_trace

__all__ = [
    "Request", "Batch", "ServingEngine",
    "Fault", "FaultInjector", "InjectedFault", "SimulatedOOM",
    "CorruptOutputError", "is_engine_fatal",
    "Backoff", "CircuitBreaker", "DegradationLadder", "WatchdogTimeout",
    "AsyncScheduler", "QueueFullError", "SchedulerDrainingError",
    "stats_dict",
    "ModelRouter", "params_bytes",
    "CONTENT_TYPE", "Counter", "Gauge", "Histogram", "Family",
    "MetricsRegistry",
    "Span", "TraceStore", "chrome_trace",
    "ServingServer", "ServerThread",
    "ServingClient", "ServerError",
]
