"""Stdlib-only async HTTP/1.1 + SSE serving front end.

Hand-rolled on ``asyncio.start_server`` — no http.server, no third-party
web framework, zero new runtime dependencies.  The endpoint surface:

* ``POST /v1/generate`` — submit a request.  JSON body::

      {"prompt": [ids...] | "text",        # text needs a server tokenizer
       "model": "name",                    # default: first registered
       "strategy": "fdm_a", "steps": 32,   # per-request DecodeConfig
       "gen_length": 64, "block_size": 16, # overrides (validated against
       "cache_policy": "prefix",           # the registry / geometry /
                                           # cache-policy axis)
       "deadline_s": 5.0,                  # max QUEUED time
       "wait": false}                      # true = block for the result

  ``wait=false`` (default) answers ``202 {"rid", "model", "stream"}``
  immediately; follow the ``stream`` URL for SSE.  Unknown strategy,
  bad geometry, or an unknown/unservable ``cache_policy`` → 400 at the
  boundary; queue at max depth → 429.

* ``GET /v1/stream/{rid}?model=name`` — Server-Sent Events: one ``block``
  event per committed semi-AR block (the natural streaming grain of
  blockwise diffusion decoding — tokens inside a block finalize
  together), possibly ``reset`` events (supervision retried the batch:
  discard earlier blocks), then exactly one terminal event (``done`` /
  ``cancelled`` / ``expired`` / ``error`` / ``shutdown``).  Events
  replay from the start, so attaching after (or long after) the decode
  still yields the full ordered stream.

* ``POST /v1/cancel`` — ``{"rid", "model"}``; true iff still queued.
* ``GET /v1/models`` — registered models (+ residency) and strategies.
* ``GET /healthz`` — liveness + per-model health (``ok`` / ``degraded``
  after a circuit-breaker engine rebuild / ``draining``) + queue depths.
* ``GET /v1/trace/{rid}?model=name`` — Chrome trace-event JSON for one
  request: scheduler lifecycle spans (queue wait, batch assembly,
  per-block decode, cache refresh, emit) and — when submitted with
  ``trace: true`` — the on-device per-step commit/revocation/skip
  counters.  Open in Perfetto or render with ``tools/trace_view.py``.
* ``GET /metrics`` — Prometheus text exposition (format 0.0.4, with
  HELP/TYPE) from a real ``MetricsRegistry``: the seed-era router/
  scheduler/decode-cache series plus latency, queue-wait, queue-depth
  and tokens-per-request histograms and per-strategy decode counters.

Backpressure answers carry ``Retry-After``: 429 at queue depth, 503
while draining for shutdown.  Bodies are bounded by Content-Length
against ``max_body_bytes`` before buffering; chunked uploads are
rejected (413).

Multi-model: requests route through a ``ModelRouter``; each resident
engine gets its own ``AsyncScheduler`` (created lazily, torn down by the
router's eviction hook so an evicted model's scheduler cannot pin its
engine — and with it the weights — past eviction).
"""
from __future__ import annotations

import asyncio
import json
import threading
import urllib.parse
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ServerConfig
from repro.core.decoder import decode_cache_info
from repro.core.strategies import available_strategies
from repro.serving.metrics import (CONTENT_TYPE, Family, MetricsRegistry)
from repro.serving.router import ModelRouter
from repro.serving.scheduler import (AsyncScheduler, QueueFullError,
                                     SchedulerDrainingError)

_MAX_HEADER_BYTES = 32 * 1024


class _HttpError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


_STATUS_TEXT = {200: "OK", 202: "Accepted", 400: "Bad Request",
                404: "Not Found", 405: "Method Not Allowed",
                413: "Payload Too Large", 429: "Too Many Requests",
                500: "Internal Server Error",
                503: "Service Unavailable"}


class ServingServer:
    """One process-local server over a ``ModelRouter``.

    ``tokenizer`` (optional, e.g. ``repro.data.CharTokenizer``) enables
    string prompts and adds decoded ``text`` fields to responses/events.
    """

    def __init__(self, router: ModelRouter,
                 scfg: ServerConfig = ServerConfig(), *, tokenizer=None):
        self.router = router
        self.scfg = scfg
        self.tokenizer = tokenizer
        self.registry = MetricsRegistry()
        self.registry.register_collector(self._collect_families)
        self._scheds: Dict[str, AsyncScheduler] = {}
        self._build_lock = asyncio.Lock()
        self._server: Optional[asyncio.AbstractServer] = None
        self.host: Optional[str] = None
        self.port: Optional[int] = None
        # tear the scheduler down WITH the engine: a live scheduler holds
        # the engine (hence the params) strongly, which would make router
        # eviction a memory no-op.  A caller-installed hook is chained,
        # not clobbered.
        self._chained_on_evict = router.on_evict
        router.on_evict = self._on_evict
        # models mid-supervised-rebuild: their eviction (inside
        # router.rebuild) must NOT tear down the scheduler driving the
        # rebuild — it adopts the fresh engine and keeps its streams
        self._rebuilding: set = set()

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> Tuple[str, int]:
        self._server = await asyncio.start_server(
            self._handle_conn, self.scfg.host, self.scfg.port)
        sock = self._server.sockets[0]
        self.host, self.port = sock.getsockname()[:2]
        return self.host, self.port

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def close(self) -> None:
        for sched in list(self._scheds.values()):
            await sched.close()
        self._scheds.clear()
        # claim-then-act: a concurrent close()/drain() must see None
        # rather than wait_closed() on a listener another task already
        # tore down (ANA202)
        server, self._server = self._server, None
        if server is not None:
            server.close()
            await server.wait_closed()

    async def drain(self, deadline_s: Optional[float] = None) -> None:
        """Graceful shutdown (the SIGTERM path): every model stops
        admission immediately (new submits answer 503 + Retry-After),
        in-flight and queued work gets up to the drain deadline to
        finish — terminal ``shutdown`` events for whatever remains —
        then the listener closes.  Streams and /healthz stay servable
        for the duration, so clients see their terminal events instead
        of a reset connection."""
        scheds = list(self._scheds.values())
        if scheds:
            await asyncio.gather(
                *(s.drain(deadline_s) for s in scheds))
        await self.close()

    # -- model plumbing ----------------------------------------------------
    def _on_evict(self, name: str, engine) -> None:
        if name not in self._rebuilding:
            sched = self._scheds.pop(name, None)
            if sched is not None:
                sched.shutdown_nowait()
        if self._chained_on_evict is not None:
            self._chained_on_evict(name, engine)

    def _rebuild_engine(self, name: str):
        """The scheduler's circuit-breaker rebuild callable (runs on an
        executor thread).  Hot-swaps the engine through the router —
        real mechanics: force-evict + fresh factory build, compiled
        runners and params of the crashed engine actually free — while
        suppressing the eviction hook's scheduler teardown: the calling
        scheduler survives, adopts the fresh engine, and its streams
        ride through the swap."""
        self._rebuilding.add(name)
        try:
            engine = self.router.rebuild(name)
        finally:
            self._rebuilding.discard(name)
        sched = self._scheds.get(name)
        if sched is not None:
            # eviction dropped the old slot's busy probe with the slot
            self.router.set_busy_probe(name, lambda s=sched: not s.idle)
        return engine

    async def scheduler(self, name: str) -> AsyncScheduler:
        """Resident scheduler for a model (engine built/touched through
        the router, so this call is what drives LRU + eviction).

        Warm path: a resident engine with a live scheduler is returned
        with a cheap LRU touch, no lock, no thread hop.  Cold path: the
        build runs on an executor thread under a lock — a cold build
        (checkpoint load + model init) or an eviction (``gc.collect``)
        can take seconds, and freezing the event loop for it would
        stall every other model's streams and /healthz — the liveness
        this layer exists to provide.  Eviction hooks fired from that
        thread re-dispatch onto the loop
        (``AsyncScheduler.shutdown_nowait`` is thread-safe).  A request
        admitted in the narrow window while its scheduler is being
        evicted gets a terminal ``shutdown`` event — visible and
        retryable, never a silent drop."""
        sched = self._scheds.get(name)
        engine = self.router.touch(name)
        if sched is not None and engine is not None and \
                sched.engine is engine:
            return sched
        async with self._build_lock:
            loop = asyncio.get_running_loop()
            engine = await loop.run_in_executor(
                None, self.router.engine, name)   # KeyError on unknown
        sched = self._scheds.get(name)
        if sched is None or sched.engine is not engine:
            if sched is not None:
                await sched.close()
            sched = AsyncScheduler(
                engine,
                max_queue_depth=self.scfg.max_queue_depth,
                default_deadline_s=self.scfg.default_deadline_s,
                stream_retain=self.scfg.stream_retain,
                svcfg=self.scfg.supervisor,
                dgcfg=self.scfg.degrade,
                rebuild_engine=lambda n=name: self._rebuild_engine(n),
                registry=self.registry, model=name,
                profile_dir=self.scfg.profile_dir)
            await sched.start()
            self._scheds[name] = sched
            self.router.set_busy_probe(
                name, lambda s=sched: not s.idle)
        return sched

    # -- connection handling -----------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    request = await self._read_request(reader)
                except _HttpError as e:
                    # parse-stage failures (malformed request line,
                    # oversized headers/body): answer, then drop the
                    # connection — the stream position is unreliable
                    self._respond(writer, e.status, {"error": e.message})
                    await writer.drain()
                    break
                if request is None:
                    break
                method, path, query, body = request
                try:
                    close = await self._route(method, path, query, body,
                                              writer)
                except _HttpError as e:
                    self._respond(writer, e.status, {"error": e.message})
                    close = False
                except (KeyError, ValueError) as e:
                    self._respond(writer, 400, {"error": str(e)})
                    close = False
                except QueueFullError as e:
                    self._respond(writer, 429, {"error": str(e)},
                                  headers=self._retry_after())
                    close = False
                except SchedulerDrainingError as e:
                    self._respond(writer, 503, {"error": str(e)},
                                  headers=self._retry_after())
                    close = False
                except (ConnectionError, asyncio.IncompleteReadError):
                    raise
                except Exception as e:
                    # catch-all: a handler bug must answer 500, not drop
                    # the connection with no status line
                    self._respond(writer, 500,
                                  {"error": f"{type(e).__name__}: {e}"})
                    close = False
                await writer.drain()
                if close:
                    break
        except (ConnectionError, asyncio.IncompleteReadError,
                asyncio.LimitOverrunError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        """Parse one HTTP/1.1 request; None on clean EOF (keep-alive)."""
        try:
            line = await reader.readline()
        except (asyncio.LimitOverrunError, ValueError):
            raise _HttpError(400, "request line too long")
        if not line or line in (b"\r\n", b"\n"):
            return None
        parts = line.decode("latin-1").strip().split()
        if len(parts) != 3:
            raise _HttpError(400, "malformed request line")
        method, target, _version = parts
        headers = {}
        total = 0
        while True:
            try:
                hline = await reader.readline()
            except (asyncio.LimitOverrunError, ValueError):
                # one header line beyond the StreamReader limit would
                # otherwise kill the handler task with no response
                raise _HttpError(400, "header line too long")
            total += len(hline)
            if total > _MAX_HEADER_BYTES:
                raise _HttpError(400, "headers too large")
            if hline in (b"\r\n", b"\n", b""):
                break
            key, _, val = hline.decode("latin-1").partition(":")
            headers[key.strip().lower()] = val.strip()
        if "chunked" in headers.get("transfer-encoding", "").lower():
            # no framing by declared size means no pre-buffer cap;
            # reject before reading a single body byte (the connection
            # drops — the stream position past the headers is unknowable
            # without decoding the chunks we just refused to read)
            raise _HttpError(413, "chunked bodies are not accepted; "
                                  "send Content-Length")
        try:
            length = int(headers.get("content-length", "0") or "0")
        except ValueError:
            raise _HttpError(400, "bad Content-Length")
        if length < 0:
            raise _HttpError(400, "bad Content-Length")
        if length > self.scfg.max_body_bytes:
            # EVERY route shares this cap, and it fires before any body
            # byte is buffered — an oversized POST costs the server its
            # header read, nothing more
            raise _HttpError(413, "request body too large")
        body = await reader.readexactly(length) if length else b""
        url = urllib.parse.urlsplit(target)
        query = dict(urllib.parse.parse_qsl(url.query))
        return method.upper(), url.path, query, body

    # -- routing -----------------------------------------------------------
    async def _route(self, method: str, path: str, query: Dict[str, str],
                     body: bytes, writer: asyncio.StreamWriter) -> bool:
        """Dispatch one request; returns True when the connection must
        close afterwards (SSE streams are close-delimited)."""
        if method == "POST" and path == "/v1/generate":
            await self._generate(body, writer)
        elif method == "GET" and path.startswith("/v1/stream/"):
            return await self._stream(path, query, writer)
        elif method == "GET" and path.startswith("/v1/trace/"):
            self._trace(path, query, writer)
        elif method == "POST" and path == "/v1/cancel":
            await self._cancel(body, writer)
        elif method == "GET" and path == "/v1/models":
            self._respond(writer, 200, {
                "models": self.router.info()["models"],
                "strategies": list(available_strategies())})
        elif method == "GET" and path == "/healthz":
            # snapshot: evictions may pop entries from an executor thread
            scheds = list(self._scheds.items())
            health = {n: s.health for n, s in scheds}
            status = "ok"
            for state in health.values():
                if state != "ok":
                    status = state
                    break
            # "ok" stays a liveness bool (the process answers); per-model
            # readiness lives in "status"/"health" — degraded = breaker
            # tripped and no clean batch yet, draining = SIGTERM received
            self._respond(writer, 200, {
                "ok": True,
                "status": status,
                "models": self.router.names(),
                "health": health,
                "queue_depth": {n: s.engine.queue_depth
                                for n, s in scheds}})
        elif method == "GET" and path == "/metrics":
            self._respond_raw(writer, 200, self.registry.render(),
                              CONTENT_TYPE)
        else:
            raise _HttpError(404, f"no route for {method} {path}")
        return False

    # -- endpoints ---------------------------------------------------------
    def _resolve_model(self, model: Optional[str]) -> str:
        """rids are per-model counters, so /v1/stream and /v1/cancel may
        only default the model when there is no ambiguity — defaulting
        across several models would read (or cancel!) some OTHER user's
        same-numbered request."""
        if model:
            return model
        names = self.router.names()
        if len(names) == 1:
            return names[0]
        raise _HttpError(400, "several models are registered; pass "
                              "'model' (rids are per-model)")

    def _parse_json(self, body: bytes) -> Dict:
        if not body:
            raise _HttpError(400, "empty body; send JSON")
        try:
            obj = json.loads(body)
        except json.JSONDecodeError as e:
            raise _HttpError(400, f"invalid JSON: {e}")
        if not isinstance(obj, dict):
            raise _HttpError(400, "JSON body must be an object")
        return obj

    def _prompt_ids(self, req: Dict) -> np.ndarray:
        prompt = req.get("prompt")
        if isinstance(prompt, str):
            if self.tokenizer is None:
                raise _HttpError(
                    400, "string prompts need a server-side tokenizer; "
                         "send token ids")
            prompt = self.tokenizer.encode(prompt)
        if not isinstance(prompt, list) or not prompt or \
                not all(isinstance(t, int) and not isinstance(t, bool)
                        for t in prompt):
            raise _HttpError(400, "prompt must be a non-empty list of "
                                  "token ids (or a string)")
        return np.asarray(prompt, np.int32)

    async def _generate(self, body: bytes,
                        writer: asyncio.StreamWriter) -> None:
        req = self._parse_json(body)
        prompt = self._prompt_ids(req)
        for key, types in (("strategy", str), ("steps", int),
                           ("gen_length", int), ("block_size", int),
                           ("cache_policy", str),
                           ("deadline_s", (int, float)),
                           ("model", str)):
            val = req.get(key)
            if val is not None and (not isinstance(val, types)
                                    or isinstance(val, bool)):
                raise _HttpError(400, f"{key} has the wrong type")
        trace = req.get("trace")
        if trace is not None and not isinstance(trace, bool):
            raise _HttpError(400, "trace must be a boolean")
        model = req.get("model") or self.router.default
        gen_length = req.get("gen_length")
        if gen_length is not None and \
                gen_length > self.scfg.max_gen_length:
            raise _HttpError(400, f"gen_length {gen_length} exceeds the "
                                  f"server cap {self.scfg.max_gen_length}")
        steps = req.get("steps")
        if steps is not None and steps > self.scfg.max_steps:
            raise _HttpError(400, f"steps {steps} exceeds the server "
                                  f"cap {self.scfg.max_steps}")
        sched = await self.scheduler(model)
        rid = sched.submit(prompt,
                           strategy=req.get("strategy"),
                           steps=req.get("steps"),
                           gen_length=gen_length,
                           block_size=req.get("block_size"),
                           cache_policy=req.get("cache_policy"),
                           trace=trace,
                           deadline_s=req.get("deadline_s"))
        if req.get("wait"):
            event = await sched.result(rid)
            self._respond(writer, 200, {"rid": rid, "model": model,
                                        **self._with_text(event)})
            return
        self._respond(writer, 202, {
            "rid": rid, "model": model,
            "stream": f"/v1/stream/{rid}?model="
                      f"{urllib.parse.quote(model)}"})

    async def _stream(self, path: str, query: Dict[str, str],
                      writer: asyncio.StreamWriter) -> bool:
        tail = path[len("/v1/stream/"):]
        if not tail.isdigit():
            raise _HttpError(404, f"bad stream id {tail!r}")
        rid = int(tail)
        model = self._resolve_model(query.get("model"))
        sched = self._scheds.get(model)
        if sched is None:
            raise _HttpError(404, f"model {model!r} has no live "
                                  f"scheduler (evicted or never used)")
        try:
            events = sched.events(rid)
            first = await anext(events)
        except KeyError:
            raise _HttpError(404, f"unknown request id {rid}")
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-store\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        await self._write_sse(writer, first)
        async for event in events:
            await self._write_sse(writer, event)
        return True          # close-delimited

    async def _write_sse(self, writer: asyncio.StreamWriter,
                         event: Dict) -> None:
        payload = json.dumps(self._with_text(event))
        writer.write(f"event: {event['type']}\n"
                     f"data: {payload}\n\n".encode())
        await writer.drain()

    def _with_text(self, event: Dict) -> Dict:
        if self.tokenizer is not None and "tokens" in event:
            return {**event, "text": self.tokenizer.decode(
                np.asarray(event["tokens"]))}
        return event

    def _trace(self, path: str, query: Dict[str, str],
               writer: asyncio.StreamWriter) -> None:
        """``GET /v1/trace/{rid}?model=name`` — Chrome trace-event JSON
        for one finished (or in-flight) request: scheduler lifecycle
        spans always; on-device per-step counters when the request was
        submitted with ``trace=true``.  Load the body in Perfetto /
        ``chrome://tracing``, or render it with tools/trace_view.py."""
        tail = path[len("/v1/trace/"):]
        if not tail.isdigit():
            raise _HttpError(404, f"bad trace id {tail!r}")
        rid = int(tail)
        model = self._resolve_model(query.get("model"))
        sched = self._scheds.get(model)
        if sched is None:
            raise _HttpError(404, f"model {model!r} has no live "
                                  f"scheduler (evicted or never used)")
        try:
            trace = sched.trace(rid)
        except KeyError:
            raise _HttpError(404, f"no trace for request id {rid} "
                                  f"(never decoded, or retired)")
        self._respond(writer, 200, trace)

    async def _cancel(self, body: bytes,
                      writer: asyncio.StreamWriter) -> None:
        req = self._parse_json(body)
        model = self._resolve_model(req.get("model"))
        rid = req.get("rid")
        if not isinstance(rid, int):
            raise _HttpError(400, "rid must be an integer")
        sched = self._scheds.get(model)
        cancelled = bool(sched and sched.cancel(rid))
        self._respond(writer, 200, {"rid": rid, "cancelled": cancelled})

    # -- metrics -----------------------------------------------------------
    def _collect_families(self) -> List[Family]:
        """Scrape-time collector: snapshot router / scheduler / decode-
        cache state into exposition families.  The series names and the
        model-first label order are the seed's — dashboards and tests
        pin them — only the HELP/TYPE metadata and escaping moved into
        ``serving.metrics``."""
        fams: List[Family] = [
            Family("repro_up", "gauge", "Server process is serving.",
                   [({}, 1)]),
        ]

        def fam(series: str, mtype: str, help: str, samples) -> None:
            fams.append(Family(f"repro_{series}", mtype, help,
                               list(samples)))

        info = self.router.info()
        for series, key, mtype, help in (
                ("router_resident_bytes", "resident_bytes", "gauge",
                 "Bytes of resident params."),
                ("router_budget_bytes", "budget_bytes", "gauge",
                 "Router residency budget."),
                ("router_evictions_total", "evictions", "counter",
                 "Models evicted for space."),
                ("router_builds_total", "builds", "counter",
                 "Model builds (cold loads)."),
                ("router_swaps_total", "swaps", "counter",
                 "Resident-model swaps."),
                ("router_rebuilds_total", "rebuilds", "counter",
                 "Faulted-model rebuilds.")):
            fam(series, mtype, help, [({}, info[key])])

        # snapshot: evictions may pop entries from an executor thread
        scheds = list(self._scheds.items())
        per_model: Dict[str, List] = {}

        def add(series: str, mtype: str, help: str, labels, value):
            per_model.setdefault(series, [mtype, help, []])[2].append(
                (labels, value))

        for name, sched in scheds:
            m = sched.metrics()
            labels = {"model": name}
            add("queue_depth", "gauge",
                "Requests waiting for batch assembly.", labels,
                m["queue_depth"])
            add("decoding", "gauge", "A decode batch is in flight.",
                labels, int(m["decoding"]))
            add("health_degraded", "gauge",
                "Scheduler is on a degradation rung.", labels,
                int(m["health"] == "degraded"))
            add("ladder_rung", "gauge",
                "Current degradation-ladder rung.", labels,
                m["ladder_rung"])
            add("breaker_trips_total", "counter",
                "Circuit-breaker trips.", labels, m["breaker_trips"])
            for counter in ("submitted", "finished", "rejected",
                            "cancelled", "expired", "errors", "batches",
                            "blocks", "retries", "requeued",
                            "quarantined", "watchdog_timeouts",
                            "engine_faults", "engine_rebuilds",
                            "rebuild_failures", "resets", "degraded"):
                add(f"requests_{counter}_total", "counter",
                    f"Lifecycle counter: {counter}.", labels, m[counter])
            for kind, fired in m["faults_injected"].items():
                add("faults_injected_total", "counter",
                    "Injected faults that fired.",
                    {"model": name, "kind": kind}, fired)
            summary = m["engine"]
            if summary:
                add("latency_seconds", "gauge",
                    "Request latency summary stats.",
                    {"model": name, "stat": "mean"},
                    summary["mean_latency_s"])
                add("latency_seconds", "gauge",
                    "Request latency summary stats.",
                    {"model": name, "stat": "p95"},
                    summary["p95_latency_s"])
                add("decode_tps", "gauge",
                    "Committed tokens per decode-second.", labels,
                    summary["decode_tps"])
                add("throughput_tps", "gauge",
                    "Committed tokens per wall-second.", labels,
                    summary["throughput_tps"])
        for series, (mtype, help, samples) in per_model.items():
            fam(series, mtype, help, samples)

        cache = decode_cache_info()
        for fld in ("entries", "runners", "hits", "misses", "traces"):
            fam(f"decode_cache_{fld}", "gauge",
                f"Decode runner cache: {fld}.",
                [({}, getattr(cache, fld))])
        return fams

    # -- response helpers --------------------------------------------------
    def _retry_after(self) -> Dict[str, str]:
        """429/503 both carry Retry-After (integer seconds per RFC
        9110): backpressure is a *schedule*, not just a refusal — the
        blocking client honors it."""
        return {"Retry-After": str(max(1, round(self.scfg.retry_after_s)))}

    def _respond(self, writer: asyncio.StreamWriter, status: int,
                 obj: Dict, headers: Optional[Dict[str, str]] = None
                 ) -> None:
        self._respond_raw(writer, status, json.dumps(obj),
                          "application/json", headers)

    def _respond_raw(self, writer: asyncio.StreamWriter, status: int,
                     text: str, ctype: str,
                     headers: Optional[Dict[str, str]] = None) -> None:
        data = text.encode()
        extra = "".join(f"{k}: {v}\r\n"
                        for k, v in (headers or {}).items())
        head = (f"HTTP/1.1 {status} {_STATUS_TEXT.get(status, '?')}\r\n"
                f"Content-Type: {ctype}\r\n"
                f"Content-Length: {len(data)}\r\n{extra}"
                f"Connection: keep-alive\r\n\r\n")
        writer.write(head.encode() + data)


class ServerThread:
    """Run a ``ServingServer`` on a dedicated thread with its own event
    loop — the in-process harness used by tests, ``benchmarks/
    serving_load.py``, and notebook/demo callers.  Blocking clients
    (``repro.serving.client``) talk to it over real sockets.

        handle = ServerThread(router, scfg).start()
        ... ServingClient(handle.host, handle.port) ...
        handle.stop()
    """

    def __init__(self, router: ModelRouter,
                 scfg: ServerConfig = ServerConfig(), *, tokenizer=None):
        self.server = ServingServer(router, scfg, tokenizer=tokenizer)
        self.host: Optional[str] = None
        self.port: Optional[int] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop: Optional[asyncio.Future] = None
        self._started = threading.Event()
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._main, daemon=True,
                                        name="repro-serving")

    def _main(self) -> None:
        try:
            asyncio.run(self._amain())
        except BaseException as e:           # surface startup failures
            self._error = e
            self._started.set()

    async def _amain(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stop = self._loop.create_future()
        try:
            self.host, self.port = await self.server.start()
        finally:
            self._started.set()
        await self._stop
        await self.server.close()

    def start(self, timeout: float = 30.0) -> "ServerThread":
        self._thread.start()
        if not self._started.wait(timeout):
            raise TimeoutError("server thread failed to start")
        if self._error is not None:
            raise self._error
        return self

    def stop(self, timeout: float = 30.0) -> None:
        if self._loop is not None and self._stop is not None and \
                not self._stop.done():
            self._loop.call_soon_threadsafe(self._stop.set_result, None)
        self._thread.join(timeout)

    def call(self, coro_fn, *args, timeout: float = 30.0):
        """Run ``await coro_fn(*args)`` on the server loop from the
        calling (non-loop) thread; returns its result.  How tests reach
        scheduler/router internals that must run on the loop thread."""
        assert self._loop is not None
        fut = asyncio.run_coroutine_threadsafe(coro_fn(*args), self._loop)
        return fut.result(timeout)
