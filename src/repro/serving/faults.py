"""Deterministic fault injection at the engine / decode boundary.

Every recovery path in the serving stack — retry, bisection quarantine,
watchdog, circuit breaker, engine rebuild, the degradation ladder — is
only trustworthy if it can be *driven* in tier-1 tests, which means the
failures themselves must be schedulable and reproducible.  A
``FaultInjector`` holds a list of ``Fault`` specs and fires them at the
block grain of ``ServingEngine.decode_batch_blocks`` (the supervision
grain: the fused drivers run the per-step forwards inside compiled XLA
programs, so the block boundary is the first host point where a failure
can be injected — and caught — without leaving the compiled path).

Fault kinds:

* ``"error"``   — raise ``InjectedFault`` before the matching block (a
  generic transient decode failure: the retry / bisection path).
* ``"nan"``     — corrupt the committed block's tokens the way NaN/inf
  logits would (an argmax over a non-finite canvas yields garbage): the
  engine's always-on output validator catches the corruption and raises
  ``CorruptOutputError``.  This exercises the *detector*, not just the
  handler.
* ``"latency"`` — sleep ``delay_s`` before the matching block (an
  artificially slow forward: the watchdog path).
* ``"oom"``     — raise ``SimulatedOOM`` (shaped like an XLA
  RESOURCE_EXHAUSTED: the engine-fatal / circuit-breaker path).

Matching is composable: ``batch_index`` counts decode *attempts* as the
injector sees them (a retried batch is a new attempt), ``rid`` makes a
fault follow one poison request into every batch that contains it
(exactly what bisection quarantine needs), ``block`` picks the block
within a matching batch, and ``times`` bounds total firings.  A seeded
``chaos_rate`` adds random background faults for soak runs — same seed,
same schedule.

The injector is attached to a ``ServingEngine`` (constructor argument or
``set_fault_injector``) and only ever mutated from the decode thread, so
its counters need no locking.
"""
from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


class InjectedFault(RuntimeError):
    """A scheduled decode failure (transient unless stated otherwise)."""


class SimulatedOOM(InjectedFault):
    """An injected engine-fatal failure, shaped like the accelerator
    runtime's out-of-memory error (supervision classifies on the
    RESOURCE_EXHAUSTED marker, same as for the real thing)."""

    def __init__(self, msg: str = "injected oom"):
        super().__init__(f"RESOURCE_EXHAUSTED: {msg}")


class CorruptOutputError(RuntimeError):
    """The engine's output validator found committed tokens outside the
    vocabulary — the downstream signature of NaN/inf logits."""


def validate_block_tokens(tokens: np.ndarray, vocab_size: int) -> None:
    """The always-on corruption detector: every committed token must be
    a valid vocabulary id.  NaN/inf logits don't raise inside the
    compiled decode — they commit garbage — so the engine checks each
    block's host-side slice before fanning it out to streams."""
    if tokens.size and ((tokens < 0) | (tokens >= vocab_size)).any():
        bad = tokens[(tokens < 0) | (tokens >= vocab_size)]
        raise CorruptOutputError(
            f"committed block contains {bad.size} out-of-vocab token(s) "
            f"(e.g. {int(bad.flat[0])}); non-finite logits upstream?")


@dataclasses.dataclass
class Fault:
    """One scheduled fault.  Fields compose as AND-filters; ``None``
    matches anything."""
    kind: str                          # error | nan | latency | oom
    batch_index: Optional[int] = None  # Nth decode attempt the injector sees
    rid: Optional[int] = None          # fires when this rid is in the batch
    block: Optional[int] = 0           # block within the matching batch
                                       # (None = every block)
    times: Optional[int] = 1           # total firings (None = unlimited)
    delay_s: float = 0.5               # latency kind: injected stall
    message: str = "injected fault"

    def __post_init__(self):
        if self.kind not in ("error", "nan", "latency", "oom"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        self.fired = 0

    def matches(self, batch_index: int, rids: Sequence[int],
                block: int) -> bool:
        if self.times is not None and self.fired >= self.times:
            return False
        if self.batch_index is not None and batch_index != self.batch_index:
            return False
        if self.rid is not None and self.rid not in rids:
            return False
        if self.block is not None and block != self.block:
            return False
        return True


class FaultInjector:
    """Schedules ``Fault``s into an engine's block-grain decode.

    ``chaos_rate`` > 0 additionally fires a random fault (drawn from
    ``chaos_kinds`` with ``random.Random(seed)``) before each block with
    that probability — the nightly soak's background noise.  Scheduled
    faults and chaos compose; determinism holds per (faults, seed,
    traffic order).
    """

    def __init__(self, faults: Sequence[Fault] = (), *,
                 chaos_rate: float = 0.0,
                 chaos_kinds: Sequence[str] = ("error", "nan", "latency"),
                 chaos_delay_s: float = 0.05,
                 seed: int = 0):
        self.faults: List[Fault] = list(faults)
        self.chaos_rate = chaos_rate
        self.chaos_kinds = tuple(chaos_kinds)
        self.chaos_delay_s = chaos_delay_s
        self._rand = random.Random(seed)
        self.batches_seen = 0          # decode attempts (retries included)
        self.counters: Dict[str, int] = {
            k: 0 for k in ("error", "nan", "latency", "oom")}

    # -- engine hooks (decode thread only) ---------------------------------
    def begin_batch(self) -> int:
        """Called once per decode attempt; returns this attempt's index."""
        bi = self.batches_seen
        self.batches_seen += 1
        return bi

    def before_block(self, batch_index: int, rids: Sequence[int],
                     block: int) -> None:
        """Fires error/oom/latency faults scheduled for this block.
        Raises or sleeps; ``nan`` faults fire in ``filter_tokens``."""
        for fault in self._firing(batch_index, rids, block,
                                  ("error", "oom", "latency")):
            if fault.kind == "latency":
                time.sleep(fault.delay_s)
            elif fault.kind == "oom":
                raise SimulatedOOM(fault.message)
            else:
                raise InjectedFault(
                    f"{fault.message} (batch {batch_index}, block {block})")

    def filter_tokens(self, batch_index: int, rids: Sequence[int],
                      block: int, tokens: np.ndarray) -> np.ndarray:
        """Applies ``nan`` faults: returns the block's tokens as a NaN
        forward would have committed them (out-of-vocab garbage the
        engine validator is expected to catch)."""
        for _fault in self._firing(batch_index, rids, block, ("nan",)):
            tokens = np.full_like(tokens, -1)
        return tokens

    def _firing(self, batch_index: int, rids: Sequence[int], block: int,
                kinds: Sequence[str]):
        fired = []
        for fault in self.faults:
            if fault.kind in kinds and \
                    fault.matches(batch_index, rids, block):
                fault.fired += 1
                self.counters[fault.kind] += 1
                fired.append(fault)
        chaos = self._chaos(kinds)
        if chaos is not None:
            fired.append(chaos)
        return fired

    def _chaos(self, kinds: Sequence[str]) -> Optional[Fault]:
        # one RNG draw per (block, kind-class) call keeps the schedule a
        # pure function of traffic order; "nan" is probed in its own
        # filter_tokens call so error-class and corrupt-class chaos stay
        # independent draws
        if not self.chaos_rate or not any(k in self.chaos_kinds
                                          for k in kinds):
            return None
        if self._rand.random() >= self.chaos_rate:
            return None
        pool = [k for k in self.chaos_kinds if k in kinds]
        if not pool:
            return None
        kind = self._rand.choice(pool)
        self.counters[kind] += 1
        return Fault(kind=kind, delay_s=self.chaos_delay_s,
                     message="chaos fault", times=None)

    # -- introspection -----------------------------------------------------
    @property
    def total_fired(self) -> int:
        return sum(self.counters.values())

    def summary(self) -> Dict[str, int]:
        return {"batches_seen": self.batches_seen, **self.counters}


def is_engine_fatal(exc: BaseException) -> bool:
    """Failure classification for supervision: does this exception mean
    the ENGINE (not the batch) is suspect?  OOM-shaped runtime errors
    poison allocator state; everything else is assumed transient /
    batch-local and goes down the retry → bisect path."""
    text = f"{type(exc).__name__}: {exc}"
    return isinstance(exc, SimulatedOOM) or \
        "RESOURCE_EXHAUSTED" in text or "Out of memory" in text


def backoff_delay(attempt: int, base_s: float, cap_s: float,
                  rand: Optional[random.Random] = None) -> float:
    """Capped exponential backoff with jitter in [0.5, 1.5) — shared by
    the scheduler's retry loop and the blocking client."""
    delay = min(cap_s, base_s * math.pow(2.0, max(attempt - 1, 0)))
    if rand is not None:
        delay *= 0.5 + rand.random()
    return min(delay, cap_s)
