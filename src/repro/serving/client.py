"""Small blocking HTTP/SSE client for the serving front end.

Stdlib-only (``http.client``); used by the tests, the load benchmark,
and the examples — and it documents the wire protocol for real clients:

    client = ServingClient(host, port)
    out = client.generate([3, 5, 2], strategy="fdm_a", wait=True)
    for name, event in client.generate_stream([3, 5, 2]):
        ...                      # "block" events, then one terminal event

Retries: connection errors and 429 backpressure are retried up to
``max_retries`` times with capped exponential backoff + seeded jitter —
a 429's ``Retry-After`` header (the server's own schedule) takes
precedence over the computed delay.  ``max_retries=0`` turns the client
back into a single-shot prober (what backpressure tests and the load
benchmark want — they *count* 429s).  A stream that has already yielded
an event is never retried: the server replays events from the start, so
a blind reconnect would hand the caller duplicates.
"""
from __future__ import annotations

import http.client
import json
import random
import time
import urllib.parse
from typing import Dict, Iterator, Optional, Tuple

from repro.serving.faults import backoff_delay

_RETRYABLE_CONN = (ConnectionError, http.client.HTTPException, OSError)


class ServerError(RuntimeError):
    """Non-2xx response; carries the HTTP status and server message."""

    def __init__(self, status: int, message: str,
                 retry_after: Optional[float] = None):
        super().__init__(f"HTTP {status}: {message}")
        self.status = status
        self.message = message
        self.retry_after = retry_after


class ServingClient:
    def __init__(self, host: str, port: int, timeout: float = 120.0, *,
                 max_retries: int = 2, backoff_base_s: float = 0.2,
                 backoff_cap_s: float = 5.0, seed: int = 0):
        self.host = host
        self.port = port
        self.timeout = timeout
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self._rand = random.Random(seed)

    # -- plumbing ----------------------------------------------------------
    def _sleep_before_retry(self, attempt: int,
                            retry_after: Optional[float]) -> None:
        if retry_after is not None and retry_after >= 0:
            time.sleep(retry_after)
            return
        time.sleep(backoff_delay(attempt, self.backoff_base_s,
                                 self.backoff_cap_s, self._rand))

    def _request(self, method: str, path: str,
                 body: Optional[Dict] = None) -> Dict:
        attempt = 0
        while True:
            try:
                return self._request_once(method, path, body)
            except ServerError as e:
                if e.status != 429 or attempt >= self.max_retries:
                    raise
                attempt += 1
                self._sleep_before_retry(attempt, e.retry_after)
            except _RETRYABLE_CONN:
                if attempt >= self.max_retries:
                    raise
                attempt += 1
                self._sleep_before_retry(attempt, None)

    def _request_once(self, method: str, path: str,
                      body: Optional[Dict] = None) -> Dict:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            payload = json.dumps(body) if body is not None else None
            conn.request(method, path, body=payload,
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            data = resp.read()
            retry_after = _parse_retry_after(
                resp.getheader("Retry-After"))
        finally:
            conn.close()
        try:
            obj = json.loads(data) if data else {}
        except json.JSONDecodeError:
            obj = {"raw": data.decode(errors="replace")}
        if resp.status >= 400:
            raise ServerError(resp.status,
                              obj.get("error", obj.get("raw", "")),
                              retry_after=retry_after)
        return obj

    # -- API ---------------------------------------------------------------
    def generate(self, prompt, *, model: Optional[str] = None,
                 strategy: Optional[str] = None,
                 steps: Optional[int] = None,
                 gen_length: Optional[int] = None,
                 block_size: Optional[int] = None,
                 cache_policy: Optional[str] = None,
                 deadline_s: Optional[float] = None,
                 trace: Optional[bool] = None,
                 wait: bool = True) -> Dict:
        """Submit a prompt (token-id list, or a string if the server has
        a tokenizer).  ``wait=True`` blocks for the final result;
        ``wait=False`` returns ``{"rid", "model", "stream"}``.
        ``trace=True`` enables on-device step telemetry for this request
        (read it back with :meth:`trace`)."""
        body = {"prompt": list(prompt) if not isinstance(prompt, str)
                else prompt, "wait": wait}
        for key, val in (("model", model), ("strategy", strategy),
                         ("steps", steps), ("gen_length", gen_length),
                         ("block_size", block_size),
                         ("cache_policy", cache_policy),
                         ("deadline_s", deadline_s),
                         ("trace", trace)):
            if val is not None:
                body[key] = val
        return self._request("POST", "/v1/generate", body)

    def stream(self, rid: int, model: Optional[str] = None
               ) -> Iterator[Tuple[str, Dict]]:
        """SSE stream for a request: yields ``(event_name, data)`` pairs,
        ending after the terminal (``final``) event.  Connection errors
        are retried only while NOTHING has been yielded yet (the server
        replays from the start — a reconnect after the first yield would
        duplicate events for the caller)."""
        path = f"/v1/stream/{rid}"
        if model:
            path += "?model=" + urllib.parse.quote(model)
        attempt = 0
        while True:
            started = False
            try:
                for item in self._stream_once(path):
                    started = True
                    yield item
                return
            except _RETRYABLE_CONN:
                if started or attempt >= self.max_retries:
                    raise
                attempt += 1
                self._sleep_before_retry(attempt, None)

    def _stream_once(self, path: str) -> Iterator[Tuple[str, Dict]]:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            conn.request("GET", path)
            resp = conn.getresponse()
            if resp.status >= 400:
                data = resp.read()
                try:
                    msg = json.loads(data).get("error", "")
                except json.JSONDecodeError:
                    msg = data.decode(errors="replace")
                raise ServerError(resp.status, msg)
            name, data_lines = None, []
            while True:
                raw = resp.readline()
                if not raw:
                    return                     # server closed the stream
                line = raw.decode().rstrip("\n").rstrip("\r")
                if line.startswith("event:"):
                    name = line[len("event:"):].strip()
                elif line.startswith("data:"):
                    data_lines.append(line[len("data:"):].strip())
                elif line == "" and data_lines:
                    event = json.loads("\n".join(data_lines))
                    yield (name or event.get("type", "message"), event)
                    if event.get("final"):
                        return
                    name, data_lines = None, []
        finally:
            conn.close()

    def generate_stream(self, prompt, **kwargs
                        ) -> Iterator[Tuple[str, Dict]]:
        """Submit then stream: yields the SSE events of a fresh request."""
        kwargs["wait"] = False
        sub = self.generate(prompt, **kwargs)
        yield from self.stream(sub["rid"], model=sub.get("model"))

    def cancel(self, rid: int, model: Optional[str] = None) -> bool:
        body = {"rid": rid}
        if model:
            body["model"] = model
        return bool(self._request("POST", "/v1/cancel", body)["cancelled"])

    def trace(self, rid: int, model: Optional[str] = None) -> Dict:
        """Chrome trace-event JSON for a finished request (``GET
        /v1/trace/{rid}``).  Feed it to Perfetto / ``chrome://tracing``
        or ``tools/trace_view.py``."""
        path = f"/v1/trace/{rid}"
        if model:
            path += "?model=" + urllib.parse.quote(model)
        return self._request("GET", path)

    def models(self) -> Dict:
        return self._request("GET", "/v1/models")

    def healthz(self) -> Dict:
        return self._request("GET", "/healthz")

    def metrics_text(self) -> str:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            conn.request("GET", "/metrics")
            resp = conn.getresponse()
            data = resp.read().decode()
        finally:
            conn.close()
        if resp.status >= 400:
            raise ServerError(resp.status, data)
        return data


def _parse_retry_after(value: Optional[str]) -> Optional[float]:
    """Delta-seconds form only (what this server emits); an HTTP-date —
    or garbage — degrades to None, i.e. computed backoff."""
    if value is None:
        return None
    try:
        return max(float(value), 0.0)
    except ValueError:
        return None
