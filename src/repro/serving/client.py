"""Small blocking HTTP/SSE client for the serving front end.

Stdlib-only (``http.client``); used by the tests, the load benchmark,
and the examples — and it documents the wire protocol for real clients:

    client = ServingClient(host, port)
    out = client.generate([3, 5, 2], strategy="fdm_a", wait=True)
    for name, event in client.generate_stream([3, 5, 2]):
        ...                      # "block" events, then one terminal event
"""
from __future__ import annotations

import http.client
import json
import urllib.parse
from typing import Dict, Iterator, Optional, Tuple


class ServerError(RuntimeError):
    """Non-2xx response; carries the HTTP status and server message."""

    def __init__(self, status: int, message: str):
        super().__init__(f"HTTP {status}: {message}")
        self.status = status
        self.message = message


class ServingClient:
    def __init__(self, host: str, port: int, timeout: float = 120.0):
        self.host = host
        self.port = port
        self.timeout = timeout

    # -- plumbing ----------------------------------------------------------
    def _request(self, method: str, path: str,
                 body: Optional[Dict] = None) -> Dict:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            payload = json.dumps(body) if body is not None else None
            conn.request(method, path, body=payload,
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            data = resp.read()
        finally:
            conn.close()
        try:
            obj = json.loads(data) if data else {}
        except json.JSONDecodeError:
            obj = {"raw": data.decode(errors="replace")}
        if resp.status >= 400:
            raise ServerError(resp.status,
                              obj.get("error", obj.get("raw", "")))
        return obj

    # -- API ---------------------------------------------------------------
    def generate(self, prompt, *, model: Optional[str] = None,
                 strategy: Optional[str] = None,
                 steps: Optional[int] = None,
                 gen_length: Optional[int] = None,
                 block_size: Optional[int] = None,
                 deadline_s: Optional[float] = None,
                 wait: bool = True) -> Dict:
        """Submit a prompt (token-id list, or a string if the server has
        a tokenizer).  ``wait=True`` blocks for the final result;
        ``wait=False`` returns ``{"rid", "model", "stream"}``."""
        body = {"prompt": list(prompt) if not isinstance(prompt, str)
                else prompt, "wait": wait}
        for key, val in (("model", model), ("strategy", strategy),
                         ("steps", steps), ("gen_length", gen_length),
                         ("block_size", block_size),
                         ("deadline_s", deadline_s)):
            if val is not None:
                body[key] = val
        return self._request("POST", "/v1/generate", body)

    def stream(self, rid: int, model: Optional[str] = None
               ) -> Iterator[Tuple[str, Dict]]:
        """SSE stream for a request: yields ``(event_name, data)`` pairs,
        ending after the terminal (``final``) event."""
        path = f"/v1/stream/{rid}"
        if model:
            path += "?model=" + urllib.parse.quote(model)
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            conn.request("GET", path)
            resp = conn.getresponse()
            if resp.status >= 400:
                data = resp.read()
                try:
                    msg = json.loads(data).get("error", "")
                except json.JSONDecodeError:
                    msg = data.decode(errors="replace")
                raise ServerError(resp.status, msg)
            name, data_lines = None, []
            while True:
                raw = resp.readline()
                if not raw:
                    return                     # server closed the stream
                line = raw.decode().rstrip("\n").rstrip("\r")
                if line.startswith("event:"):
                    name = line[len("event:"):].strip()
                elif line.startswith("data:"):
                    data_lines.append(line[len("data:"):].strip())
                elif line == "" and data_lines:
                    event = json.loads("\n".join(data_lines))
                    yield (name or event.get("type", "message"), event)
                    if event.get("final"):
                        return
                    name, data_lines = None, []
        finally:
            conn.close()

    def generate_stream(self, prompt, **kwargs
                        ) -> Iterator[Tuple[str, Dict]]:
        """Submit then stream: yields the SSE events of a fresh request."""
        kwargs["wait"] = False
        sub = self.generate(prompt, **kwargs)
        yield from self.stream(sub["rid"], model=sub.get("model"))

    def cancel(self, rid: int, model: Optional[str] = None) -> bool:
        body = {"rid": rid}
        if model:
            body["model"] = model
        return bool(self._request("POST", "/v1/cancel", body)["cancelled"])

    def models(self) -> Dict:
        return self._request("GET", "/v1/models")

    def healthz(self) -> Dict:
        return self._request("GET", "/healthz")

    def metrics_text(self) -> str:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            conn.request("GET", "/metrics")
            resp = conn.getresponse()
            data = resp.read().decode()
        finally:
            conn.close()
        if resp.status >= 400:
            raise ServerError(resp.status, data)
        return data
