"""Fault-injection smoke / chaos soak for the serving stack.

Boots the full stack (tiny untrained model → engine with a seeded
``FaultInjector`` → supervised scheduler → HTTP server) and pushes
concurrent traffic through it while faults fire — one scheduled poison
request plus seeded background chaos — then checks the invariants the
supervision layer guarantees:

* every submitted request reaches exactly one terminal state
  (``ok`` / ``error`` / ``expired``) — nothing hangs, nothing is lost;
* the poison request is quarantined as ``error``, not ``ok``;
* at least one injected fault actually fired (the harness is live);
* the server still answers /healthz and /metrics afterwards.

Exit 0 = all invariants hold; exit 1 (with a summary) otherwise.

CI runs the quick profile on every push (``--requests 8``); the nightly
job runs the soak (``--soak``: more traffic, higher chaos rate).  Same
seed → same fault schedule, so a CI failure reproduces locally:

    PYTHONPATH=src python tools/fault_smoke.py --requests 8 --chaos 0.1
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import jax
import numpy as np

from repro.configs import (DecodeConfig, SupervisorConfig, get_config)
from repro.configs.base import RouterConfig, ServerConfig
from repro.models.model import init_model
from repro.serving import (Fault, FaultInjector, ModelRouter,
                           ServerThread, ServingClient, ServingEngine)


def run(n_requests: int = 8, chaos_rate: float = 0.1, seed: int = 7,
        concurrency: int = 4) -> int:
    cfg = get_config("llada-8b").reduced()
    dcfg = DecodeConfig(gen_length=16, block_size=8, steps=16,
                        strategy="probability")
    params = init_model(jax.random.PRNGKey(0), cfg)
    # rid 0 is the scheduled poison: it must end as a quarantined error
    # no matter what the background chaos does around it
    injector = FaultInjector(
        [Fault(kind="error", rid=0, times=None, message="poison")],
        chaos_rate=chaos_rate, seed=seed,
        chaos_kinds=("error", "nan", "latency"), chaos_delay_s=0.02)

    def factory():
        return ServingEngine(params, cfg, dcfg, max_batch=4,
                             fault_injector=injector)

    router = ModelRouter(RouterConfig())
    router.register("tiny", factory)
    svcfg = SupervisorConfig(max_retries=2, backoff_base_s=0.01,
                             backoff_cap_s=0.05, breaker_threshold=3)
    handle = ServerThread(
        router, ServerConfig(port=0, supervisor=svcfg)).start()
    failures = []
    try:
        client = ServingClient(handle.host, handle.port, max_retries=3,
                               backoff_base_s=0.05, backoff_cap_s=0.5)
        results = [None] * n_requests
        errors = []

        def worker(i: int) -> None:
            prompt = [3, 5, 2, 7, 4, (i % 7) + 1]
            try:
                results[i] = client.generate(prompt, wait=True)
            except Exception as e:          # invariant breach, not flow
                errors.append((i, repr(e)))

        t0 = time.perf_counter()
        pending = list(range(n_requests))
        while pending:
            wave = [threading.Thread(target=worker, args=(i,))
                    for i in pending[:concurrency]]
            pending = pending[concurrency:]
            for t in wave:
                t.start()
            for t in wave:
                t.join(timeout=300)
                if t.is_alive():
                    failures.append("request thread hung (>300s)")
        wall = time.perf_counter() - t0

        statuses = [r["status"] if r else None for r in results]
        counts = {s: statuses.count(s) for s in set(statuses)}
        if errors:
            failures.append(f"client-visible exceptions: {errors}")
        if any(r is None for r in results) and not errors:
            failures.append("request finished with no terminal result")
        # concurrent submission order decides rids: find rid 0 by rid
        poison = next((r for r in results if r and r.get("rid") == 0),
                      None)
        if poison is None or poison["status"] != "error":
            failures.append(
                f"poison rid 0 ended "
                f"{poison and poison['status']!r}, want 'error'")
        if counts.get("ok", 0) < 1:
            failures.append("no request survived the chaos")
        if injector.total_fired < 1:
            failures.append("no fault fired — the harness is dead")
        health = client.healthz()
        if not health.get("ok"):
            failures.append(f"healthz after soak: {health}")
        metrics = client.metrics_text()
        for needle in ("repro_requests_quarantined_total",
                       "repro_faults_injected_total"):
            if needle not in metrics:
                failures.append(f"metrics missing {needle}")
        print(f"fault smoke: {n_requests} requests in {wall:.1f}s → "
              f"{counts}; faults fired: {injector.summary()}")
    finally:
        handle.stop()

    if failures:
        for f in failures:
            print(f"INVARIANT VIOLATED: {f}", file=sys.stderr)
        return 1
    print("fault smoke OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--chaos", type=float, default=0.1,
                    help="per-block background fault probability")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--soak", action="store_true",
                    help="nightly profile: 48 requests, chaos 0.15")
    args = ap.parse_args()
    if args.soak:
        args.requests = max(args.requests, 48)
        args.chaos = max(args.chaos, 0.15)
    sys.exit(run(args.requests, args.chaos, args.seed, args.concurrency))


if __name__ == "__main__":
    main()
