"""Terminal viewer for the Chrome trace-event JSON that
``GET /v1/trace/{rid}`` (and ``ServingClient.trace``) returns.

The JSON loads directly into Perfetto / ``chrome://tracing``; this tool
is for the ssh-only case — it prints the scheduler lifecycle spans as a
proportional timeline and, when the request was submitted with
``trace: true``, a per-step device table (block, commits, revocations,
skipped forwards, FDM-A phase) plus the commit total, which equals
``tokens_generated`` by construction of the commit histogram.

Input is a file path or an http(s) URL:

    PYTHONPATH=src python tools/trace_view.py trace.json
    PYTHONPATH=src python tools/trace_view.py \
        http://localhost:8411/v1/trace/0?model=tiny

Stdlib-only; no repro imports, so it runs against a saved trace on a
machine without the repo installed.
"""
from __future__ import annotations

import argparse
import json
import sys
import urllib.request
from typing import Dict, List

BAR_WIDTH = 40


def load(source: str) -> Dict:
    if source.startswith(("http://", "https://")):
        with urllib.request.urlopen(source) as resp:
            return json.loads(resp.read().decode())
    with open(source) as fh:
        return json.load(fh)


def _spans(events: List[Dict]) -> List[Dict]:
    return sorted((e for e in events if e.get("ph") == "X"
                   and e.get("cat") != "device"),
                  key=lambda e: e.get("ts", 0.0))


def _device_steps(events: List[Dict]) -> List[Dict]:
    """Pair each ``step i`` duration event with its ``commits`` counter
    event (same ts by construction)."""
    counters = {e["ts"]: e["args"] for e in events
                if e.get("ph") == "C" and e.get("name") == "commits"}
    steps = []
    for e in events:
        if e.get("ph") == "X" and e.get("cat") == "device":
            steps.append({**e.get("args", {}),
                          **counters.get(e["ts"], {})})
    return sorted(steps, key=lambda s: s.get("step", 0))


def render(trace: Dict, out=sys.stdout) -> None:
    events = trace.get("traceEvents", [])
    meta = trace.get("otherData", {})
    if meta:
        pairs = ", ".join(f"{k}={v}" for k, v in meta.items())
        print(f"request: {pairs}", file=out)

    spans = _spans(events)
    if spans:
        t_lo = min(e["ts"] for e in spans)
        t_hi = max(e["ts"] + e.get("dur", 0.0) for e in spans)
        extent = max(t_hi - t_lo, 1e-9)
        name_w = max(len(e["name"]) for e in spans)
        print(f"\nspans ({(t_hi - t_lo) / 1e3:.2f} ms total):", file=out)
        for e in spans:
            start = int((e["ts"] - t_lo) / extent * BAR_WIDTH)
            width = max(int(e.get("dur", 0.0) / extent * BAR_WIDTH), 1)
            bar = " " * start + "#" * min(width, BAR_WIDTH - start)
            print(f"  {e['name']:<{name_w}} |{bar:<{BAR_WIDTH}}| "
                  f"{e.get('dur', 0.0) / 1e3:9.3f} ms", file=out)

    steps = _device_steps(events)
    if steps:
        print(f"\ndevice steps ({len(steps)}):", file=out)
        header = f"  {'step':>4} {'block':>5} {'commits':>7} " \
                 f"{'revoked':>7} {'skipped':>7} {'phase':>5}"
        print(header, file=out)
        total = 0
        for s in steps:
            commits = s.get("commits", 0)
            total += commits
            phase = s.get("phase", "")
            print(f"  {s.get('step', '?'):>4} {s.get('block', '?'):>5} "
                  f"{commits:>7} {s.get('revocations', 0):>7} "
                  f"{s.get('skipped', 0):>7} {phase!s:>5}", file=out)
        print(f"  total committed tokens: {total}", file=out)
    elif spans:
        print("\n(no device steps — request was not submitted with "
              "trace=true)", file=out)
    if not spans and not steps:
        print("empty trace", file=out)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("source", help="trace JSON file path or URL")
    args = parser.parse_args(argv)
    render(load(args.source))
    return 0


if __name__ == "__main__":
    sys.exit(main())
