"""One-time (PR 5) formatting-normalization sweep, and the drift report
that backs the now-gating CI `ruff format --check` step.

This container ships no formatter binary (ruff/black are absent and the
environment is offline), so the PR-5 normalization pass was done with
this script + hand fixes instead of `ruff format`:

* STRING quote normalization to double quotes (tokenize-based, skipping
  strings whose content contains a double quote — matching the
  formatter's quote rule exactly);
* a report of remaining mechanically-detectable drift (lines over the
  88-column limit) for hand fixing.

What it cannot do is re-wrap hand-aligned continuation lines into
Black-style exploded form — that part of the normalization is finished
by the first ruff-equipped environment running `ruff format` and
committing (one mechanical command; the CI gate enforces the tree stays
normalized from then on).

    python tools/normalize_format.py [--write] [paths...]
"""
from __future__ import annotations

import argparse
import io
import sys
import tokenize
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_PATHS = ["src", "tests", "benchmarks", "examples", "tools"]
LINE_LIMIT = 88


def requote(tok_string: str) -> str:
    """Single→double quotes when the content allows it (the formatter's
    preferred-quotes rule): prefix preserved, never when a double quote
    (or an escape that could interact) appears in the body."""
    body = tok_string
    prefix = ""
    while body and body[0] not in "'\"":
        prefix, body = prefix + body[0], body[1:]
    if not body.startswith("'"):
        return tok_string
    quote = "'''" if body.startswith("'''") else "'"
    inner = body[len(quote):-len(quote)]
    if '"' in inner or "\\" in inner:
        return tok_string
    return prefix + '"' * len(quote) + inner + '"' * len(quote)


def normalize_file(path: Path, write: bool) -> int:
    src = path.read_text()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except tokenize.TokenError:
        print(f"  [skip, tokenize failed] {path}")
        return 0
    changed = 0
    out = []
    for tok in tokens:
        if tok.type == tokenize.STRING:
            new = requote(tok.string)
            if new != tok.string:
                changed += 1
                tok = tok._replace(string=new)
        out.append(tok)
    if changed and write:
        path.write_text(tokenize.untokenize(
            (t.type, t.string, t.start, t.end, t.line) for t in out))
    return changed


def report_long_lines(path: Path) -> int:
    count = 0
    for i, line in enumerate(path.read_text().splitlines(), 1):
        if len(line) > LINE_LIMIT:
            print(f"  {path}:{i}: {len(line)} cols")
            count += 1
    return count


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="apply quote normalization (default: report)")
    ap.add_argument("paths", nargs="*", default=DEFAULT_PATHS)
    args = ap.parse_args()
    files = sorted(f for p in args.paths
                   for f in (ROOT / p).rglob("*.py"))
    requoted = sum(normalize_file(f, args.write) for f in files)
    print(f"[{'re' if args.write else 'would re'}quote "
          f"{requoted} strings across {len(files)} files]")
    print(f"lines over {LINE_LIMIT} columns (fix by hand):")
    long_lines = sum(report_long_lines(f) for f in files)
    if not long_lines:
        print("  none")
    sys.exit(1 if long_lines else 0)


if __name__ == "__main__":
    main()
