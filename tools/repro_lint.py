#!/usr/bin/env python
"""Checkout-friendly wrapper over ``python -m repro.analysis``.

Prepends ``src/`` relative to the repo root so it runs without
PYTHONPATH, then defers entirely to ``repro.analysis.cli``:

    python tools/repro_lint.py src
    python tools/repro_lint.py --list-rules
"""
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    try:
        rc = main()
    except BrokenPipeError:    # stdout piped into a closed head/grep
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        rc = 0
    sys.exit(rc)
