"""Sharding-rule unit tests (AbstractMesh — no 512-device requirement) and
a subprocess integration test for the real dry-run."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.models.model import init_decode_state, init_model
from repro.parallel.sharding import cache_pspecs, param_pspecs

# jax 0.4.37's AbstractMesh takes a single shape_tuple of (name, size)
# pairs (newer jax split it into (shape, axis_names) — the call that used
# to live here and broke collection)
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _leaf_specs(arch, mesh=MESH):
    cfg = get_config(arch)
    p_sds = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(p_sds, mesh)
    flat_s, _ = jax.tree_util.tree_flatten_with_path(specs)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(p_sds)
    return {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): (leaf.shape, spec)
            for (path, leaf), (_, spec) in zip(flat_p, flat_s)}


def _axis_size(mesh, ax):
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x22b",
                                  "deepseek-v2-236b", "whisper-medium",
                                  "xlstm-125m", "hymba-1.5b"])
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["1pod", "2pod"])
def test_param_specs_divisible_and_unique(arch, mesh):
    """Every sharded dim divides its axes; no axis is used twice."""
    for path, (shape, spec) in _leaf_specs(arch, mesh).items():
        used = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                continue
            assert dim % _axis_size(mesh, ax) == 0, (path, shape, spec)
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                assert a not in used, (path, spec)
                used.append(a)


def test_expert_parallel_when_divisible():
    """DeepSeek: 160 experts % 16 == 0 -> expert axis on `model`."""
    specs = _leaf_specs("deepseek-v2-236b")
    gate = [v for k, v in specs.items() if k.endswith("moe/w_gate")]
    assert gate, "no stacked expert weights found"
    for shape, spec in gate:
        # (layer_stack, E, d, ff) — expert dim carries `model`
        assert spec[-3] == "model", (shape, spec)


def test_mixtral_falls_back_to_ffn_tp():
    """Mixtral: 8 experts % 16 != 0 -> ffn-dim tensor parallelism."""
    specs = _leaf_specs("mixtral-8x22b")
    for k, (shape, spec) in specs.items():
        if k.endswith("moe/w_gate"):
            assert spec[-3] is None, (k, shape, spec)
            assert spec[-1] == "model", (k, shape, spec)


def test_odd_vocab_replicated():
    """Whisper vocab 51865 does not divide 16 -> embed vocab replicated."""
    specs = _leaf_specs("whisper-medium")
    shape, spec = next(v for k, v in specs.items()
                       if k.endswith("embed/tok"))
    assert spec[0] is None


def test_cache_specs_batch_vs_context_parallel():
    cfg = get_config("qwen3-14b")
    state = jax.eval_shape(
        lambda: init_decode_state(cfg, 128, 1024, jnp.bfloat16))
    specs = cache_pspecs(state, MESH, batch=128)
    ks = [s for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)) if len(s) >= 4]
    assert any(("data",) == s[1] or "data" in (s[1] or ()) for s in ks), ks

    # batch=1 (long-context): the long axis gets the data axes instead
    state1 = jax.eval_shape(
        lambda: init_decode_state(cfg, 1, 32768, jnp.bfloat16))
    specs1 = cache_pspecs(state1, MESH, batch=1)
    flat = [s for s in jax.tree.leaves(
        specs1, is_leaf=lambda x: isinstance(x, P)) if len(s) >= 4]
    assert any(s[2] is not None for s in flat), flat


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("REPRO_RUN_SLOW"),
                    reason="~8 min subprocess dry-run; set REPRO_RUN_SLOW=1 "
                           "to include it (verified passing 2026-07)")
def test_dryrun_subprocess_smoke():
    """The real thing, in a subprocess (own XLA device-count flag)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "stablelm-3b", "--shape", "decode_32k", "--skip-full"],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "1 ok, 0 failed" in proc.stdout
