"""Per-architecture smoke tests (assignment contract: reduced variant of
the same family — ≤2 layers, d_model ≤ 512, ≤4 experts — one forward and
one train step on CPU, asserting output shapes and no NaNs).

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, TrainConfig, get_config
from repro.models.model import (decode_step, forward, init_decode_state,
                                init_model)
from repro.training.optimizer import adamw_init
from repro.training.trainer import make_train_step

B, L = 2, 16


def _extras(cfg, rng):
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jax.random.normal(
            rng, (B, min(cfg.encdec.encoder_seq, 32) or 32, cfg.d_model))
    if cfg.encdec is not None and cfg.encdec.frontend == "vision_stub":
        kw["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.encdec.num_patch_tokens, cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ["llada-8b"])
def test_reduced_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.moe.num_experts <= 4
    params = init_model(rng, cfg)
    toks = jax.random.randint(rng, (B, L), 0, cfg.vocab_size)
    logits, aux = forward(params, toks, cfg, **_extras(cfg, rng))
    assert logits.shape == (B, L, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    extras = ()
    batch = {"tokens": jax.random.randint(rng, (B, L), 0,
                                          cfg.vocab_size - 1),
             "maskable": jnp.ones((B, L), bool).at[:, :4].set(False)}
    kw = _extras(cfg, rng)
    if "enc_embeds" in kw:
        batch["enc_embeds"] = kw["enc_embeds"]
        extras = ("enc_embeds",)
    if "patch_embeds" in kw:
        batch["patch_embeds"] = kw["patch_embeds"]
        extras = ("patch_embeds",)
    tcfg = TrainConfig(steps=2)
    step = make_train_step(cfg, tcfg, extra_inputs=extras)
    params = init_model(rng, cfg)
    opt = adamw_init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, rng, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["loss"]) > 0
    # at least one parameter actually moved
    moved = any(bool(jnp.any(a != b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_decode_step(arch, rng):
    cfg = get_config(arch).reduced()
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(rng, (B, 32, cfg.d_model))
    params = init_model(rng, cfg)
    state = init_decode_state(cfg, B, L, jnp.float32, enc_out=enc)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size - 1)
    pos = jnp.full((B, 1), L - 1, jnp.int32)
    logits, state2 = decode_step(params, tok, pos, state, cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


def test_decode_matches_forward_for_dense(rng):
    """Cached single-token decode must agree with the full forward on the
    same committed sequence.

    Exactness holds for ONE layer only: with deeper stacks the frozen-
    prefix cache is the documented approximation (layer-n K/V of early
    tokens were computed before later tokens existed — see DESIGN.md §3,
    the Fast-dLLM/dKV-cache approximation the paper's related work uses).
    """
    cfg = get_config("stablelm-3b").reduced(num_layers=1)
    params = init_model(rng, cfg)
    toks = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size - 1)
    full_logits, _ = forward(params, toks, cfg)

    # build the cache by decoding tokens 0..6 sequentially, then compare
    # the logits for the final token
    state = init_decode_state(cfg, 1, 8, jnp.float32)
    for i in range(8):
        logits, state = decode_step(params, toks[:, i:i + 1],
                                    jnp.full((1, 1), i, jnp.int32),
                                    state, cfg)
    # position 7 decode sees tokens 0..7 -- forward position 7 sees all 8;
    # bidirectional attention means full forward also attends "future"
    # masked positions, so compare only the *last* position, whose visible
    # set matches.
    import numpy as np
    np.testing.assert_allclose(np.asarray(logits[0, 0]),
                               np.asarray(full_logits[0, 7]),
                               rtol=2e-3, atol=2e-3)
