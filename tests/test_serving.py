"""ServingEngine scheduling semantics: bucket fairness, pad accounting,
per-request DecodeConfig overrides, cancellation, deadlines, and the
block-grain decode generator the async scheduler drives."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import DecodeConfig, get_config
from repro.core import Decoder
from repro.models.model import init_model
from repro.serving import ServingEngine

CFG = get_config("llada-8b").reduced()


@pytest.fixture(scope="module")
def params():
    """Untrained tiny model — scheduling semantics, not quality."""
    return init_model(jax.random.PRNGKey(0), CFG)


def _dcfg(**over):
    base = dict(gen_length=16, block_size=8, steps=16,
                strategy="probability")
    base.update(over)
    return DecodeConfig(**base)


def _engine(params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("length_bucket", 8)
    return ServingEngine(params, CFG, _dcfg(), **kw)


def _prompt(length, fill=3):
    return np.full((length,), fill, np.int32)


# --------------------------------------------------------------------------
# cancellation
# --------------------------------------------------------------------------

def test_cancel_queued_request(params):
    engine = _engine(params)
    keep_a = engine.submit(_prompt(6))
    victim = engine.submit(_prompt(6))
    keep_b = engine.submit(_prompt(6))
    assert engine.cancel(victim) is True
    assert engine.queue_depth == 2
    req = engine.result(victim)
    assert req.status == "cancelled"
    assert req.cancelled and req.result is None and req.stats is None
    finished = engine.step()
    assert sorted(finished) == sorted([keep_a, keep_b])
    # the cancelled request was never decoded and summary() excludes it
    assert engine.summary()["requests"] == 2


def test_cancel_is_idempotent_and_safe(params):
    engine = _engine(params)
    rid = engine.submit(_prompt(6))
    engine.run_until_idle()
    assert engine.cancel(rid) is False          # already finished
    assert engine.result(rid).status == "done"
    assert engine.cancel(999) is False          # never submitted


# --------------------------------------------------------------------------
# bucket fairness + pad accounting under mixed-length traffic
# --------------------------------------------------------------------------

def test_oldest_bucket_served_first(params):
    """The bucket holding the OLDEST request is always served next, even
    when a younger bucket has more members queued."""
    engine = _engine(params)
    old = engine.submit(_prompt(13))            # bucket 16, oldest
    young = [engine.submit(_prompt(5)) for _ in range(3)]   # bucket 8
    first = engine.step()
    assert first == [old]
    second = engine.step()
    assert sorted(second) == sorted(young)


def test_pads_never_exceed_batch_max_minus_real_length(params):
    """Mixed lengths inside one bucket: every request's mask pad must be
    exactly batch-max-real-length minus its own length (the engine pads
    to the batch max, never the bucket ceiling), and uniform batches see
    zero padding."""
    engine = _engine(params)
    lens = [5, 7, 6]                            # all in the 8-ceiling bucket
    rids = [engine.submit(_prompt(n)) for n in lens]
    batch = engine.select_batch()
    assert sorted(r.rid for r in batch.requests) == sorted(rids)
    batch_max = max(lens)
    for req, length in zip(batch.requests, lens):
        assert req.pad_cols == batch_max - length
        assert req.pad_cols <= batch_max - length   # never exceeds
        assert req.pad_cols < engine.length_bucket  # < bucket ceiling
    engine.decode_batch(batch)
    for rid, length in zip(rids, lens):
        req = engine.result(rid)
        assert req.result.shape == (length + 16,)   # pads sliced off
        assert not (req.result[length:] == CFG.mask_token_id).any()
    # uniform-length traffic: zero pads
    uni = [engine.submit(_prompt(6)) for _ in range(3)]
    batch = engine.select_batch()
    assert [r.pad_cols for r in batch.requests] == [0, 0, 0]
    engine.decode_batch(batch)
    assert all(engine.result(r).status == "done" for r in uni)


# --------------------------------------------------------------------------
# per-request DecodeConfig overrides
# --------------------------------------------------------------------------

def test_overrides_validated_at_submit(params):
    engine = _engine(params)
    with pytest.raises(KeyError, match="unknown strategy"):
        engine.submit(_prompt(6), strategy="nope")
    with pytest.raises(ValueError, match="not a multiple"):
        engine.submit(_prompt(6), gen_length=12, block_size=8)
    with pytest.raises(ValueError, match="infeasible"):
        engine.submit(_prompt(6), steps=1)      # 2 blocks need ≥ 2 steps
    with pytest.raises(ValueError, match="positive"):
        engine.submit(_prompt(6), block_size=0)   # not ZeroDivisionError
    with pytest.raises(ValueError, match="positive"):
        engine.submit(_prompt(6), gen_length=-8)
    assert engine.queue_depth == 0              # nothing bad was queued


def test_mixed_strategy_requests_never_share_a_batch(params):
    """Same prompt bucket, different effective DecodeConfig → separate
    batches (batching across configs would decode one request with
    another's settings)."""
    engine = _engine(params)
    a = engine.submit(_prompt(6))                        # base: probability
    b = engine.submit(_prompt(6), strategy="entropy")
    c = engine.submit(_prompt(6))
    first = engine.step()
    assert sorted(first) == sorted([a, c])               # same-config pair
    second = engine.step()
    assert second == [b]
    # each decoded under its own config, bit-identical to a direct decode
    direct = Decoder(params, CFG,
                     _dcfg(strategy="entropy")).generate(
        jax.random.PRNGKey(7), np.asarray([_prompt(6)]))[0]
    np.testing.assert_array_equal(engine.result(b).result,
                                  np.asarray(direct)[0])


def test_gen_length_override_changes_result_shape(params):
    engine = _engine(params)
    rid = engine.submit(_prompt(6), gen_length=8, steps=8)
    engine.run_until_idle()
    req = engine.result(rid)
    assert req.result.shape == (6 + 8,)
    assert req.stats.tokens_generated == 8


# --------------------------------------------------------------------------
# deadlines (admission control)
# --------------------------------------------------------------------------

def test_expired_requests_are_reaped_not_decoded(params):
    engine = _engine(params)
    doomed = engine.submit(_prompt(6), deadline_s=0.0)
    alive = engine.submit(_prompt(6))
    time.sleep(0.01)                            # pass the deadline
    finished = engine.step()
    assert finished == [alive]
    req = engine.result(doomed)
    assert req.status == "expired"
    assert req.expired and req.result is None
    assert engine.summary()["requests"] == 1    # expired never decoded


# --------------------------------------------------------------------------
# block-grain decode (what the async scheduler drives)
# --------------------------------------------------------------------------

def test_decode_batch_blocks_streams_commit_order(params):
    """The generator yields one host-side token slice per committed block
    in commit order, fires the engine-level hook identically, and
    finishes the batch exactly like decode_batch."""
    recorded = []
    engine = _engine(
        params,
        on_block_committed=lambda reqs, blk, lo, hi, x:
            recorded.append((blk, lo, hi)))
    rid = engine.submit(_prompt(6))
    batch = engine.select_batch()
    blocks = engine.decode_batch_blocks(batch)
    events = []
    while True:
        try:
            events.append(next(blocks))
        except StopIteration as fin:
            finished = fin.value
            break
    assert finished == [rid]
    assert [e[0] for e in events] == [0, 1]
    assert [(e[1], e[2]) for e in events] == [(6, 14), (14, 22)]
    assert recorded == [(0, 6, 14), (1, 14, 22)]
    req = engine.result(rid)
    # the streamed slices concatenate to the final generation
    streamed = np.concatenate([e[3][0] for e in events])
    np.testing.assert_array_equal(streamed, req.result[6:])
    assert req.stats is not None and req.stats.steps > 0


def test_block_grain_matches_whole_request_driver(params):
    """decode_batch_blocks (per-block dispatches) and decode_batch
    (single whole-request dispatch) must produce bit-identical results —
    the serving layer leans on the three-driver parity guarantee."""
    engine = _engine(params)
    r1 = engine.submit(_prompt(6))
    batch1 = engine.select_batch()
    rng = batch1.rng                            # reuse the same batch rng
    blocks = engine.decode_batch_blocks(batch1)
    while True:
        try:
            next(blocks)
        except StopIteration:
            break
    r2 = engine.submit(_prompt(6))
    batch2 = engine.select_batch()
    batch2 = dataclasses.replace(batch2, rng=rng)
    engine.decode_batch(batch2)
    np.testing.assert_array_equal(engine.result(r1).result,
                                  engine.result(r2).result)
