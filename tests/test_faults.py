"""Fault injection + supervision: the deterministic failure matrix.

Every recovery path is driven by the ``FaultInjector`` at the engine's
block grain — scheduled errors, NaN-style token corruption (caught by
the always-on output validator), simulated OOM (circuit breaker →
engine rebuild), injected latency (watchdog) — and asserted at the
scheduler's event streams: the poison request gets exactly ONE terminal
``error`` event, co-batched requests survive bit-identical to a
fault-free decode, the worker loop never dies.
"""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import (DecodeConfig, DegradeConfig, LadderRung,
                           SupervisorConfig, get_config)
from repro.configs.base import RouterConfig, ServerConfig
from repro.core import Decoder
from repro.models.model import init_model
from repro.serving import (AsyncScheduler, CorruptOutputError, Fault,
                           FaultInjector, InjectedFault, ModelRouter,
                           ServingEngine, SimulatedOOM, is_engine_fatal)
from repro.serving.faults import backoff_delay, validate_block_tokens
from repro.serving.supervisor import (Backoff, CircuitBreaker,
                                      DegradationLadder, WatchdogTimeout,
                                      bisect, classify_failure)

CFG = get_config("llada-8b").reduced()
DCFG = DecodeConfig(gen_length=16, block_size=8, steps=16,
                    strategy="probability")
# fast supervision for tests: near-zero backoff, small breaker window
SVCFG = SupervisorConfig(max_retries=2, backoff_base_s=0.001,
                         backoff_cap_s=0.002, breaker_threshold=2,
                         breaker_window_s=60.0)


@pytest.fixture(scope="module")
def params():
    return init_model(jax.random.PRNGKey(0), CFG)


def _engine(params, faults=(), **kw):
    kw.setdefault("max_batch", 4)
    inj = FaultInjector(faults) if faults else None
    return ServingEngine(params, CFG, DCFG, fault_injector=inj, **kw)


def _prompt(i=0):
    return np.asarray([3, 5, 2, 7, 4, 6 + i], np.int32)


def _direct(params, prompt):
    out, _ = Decoder(params, CFG, DCFG).generate(
        jax.random.PRNGKey(99), np.asarray(prompt, np.int32)[None])
    return np.asarray(out)[0]


# --------------------------------------------------------------------------
# the injector itself (no model, no asyncio)
# --------------------------------------------------------------------------

def test_fault_matching_and_firing_budget():
    f = Fault(kind="error", batch_index=1, block=0, times=1)
    assert not f.matches(0, [1, 2], 0)          # wrong batch
    assert not f.matches(1, [1, 2], 1)          # wrong block
    assert f.matches(1, [1, 2], 0)
    inj = FaultInjector([f])
    assert inj.begin_batch() == 0
    inj.before_block(0, [1, 2], 0)              # batch 0: no fire
    with pytest.raises(InjectedFault):
        inj.before_block(1, [1, 2], 0)
    # times=1: spent — a retry of the same batch index sails through
    inj.before_block(1, [1, 2], 0)
    assert inj.counters["error"] == 1


def test_fault_rid_follows_poison_request():
    """A rid-keyed fault fires in EVERY batch containing the poison rid
    — the contract bisection quarantine depends on."""
    f = Fault(kind="error", rid=7, times=None)
    inj = FaultInjector([f])
    with pytest.raises(InjectedFault):
        inj.before_block(0, [5, 6, 7, 8], 0)
    with pytest.raises(InjectedFault):
        inj.before_block(1, [7], 0)
    inj.before_block(2, [5, 6], 0)              # poison not present
    assert inj.counters["error"] == 2


def test_unknown_fault_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(kind="explode")


def test_nan_fault_corrupts_and_validator_catches():
    inj = FaultInjector([Fault(kind="nan", block=0)])
    tokens = np.asarray([[1, 2], [3, 4]])
    bad = inj.filter_tokens(0, [1, 2], 0, tokens)
    assert (bad == -1).all()
    with pytest.raises(CorruptOutputError, match="out-of-vocab"):
        validate_block_tokens(bad, CFG.vocab_size)
    validate_block_tokens(tokens, CFG.vocab_size)   # clean passes


def test_chaos_mode_is_seeded_and_counted():
    def schedule(seed):
        inj = FaultInjector([], chaos_rate=0.5, seed=seed,
                            chaos_kinds=("error",))
        fired = []
        for block in range(32):
            try:
                inj.before_block(0, [1], block)
                fired.append(False)
            except InjectedFault:
                fired.append(True)
        return fired, inj.total_fired

    a, na = schedule(7)
    b, nb = schedule(7)
    c, _ = schedule(8)
    assert a == b and na == nb          # same seed → same schedule
    assert a != c                       # different seed → different one
    assert 0 < na < 32


def test_oom_classification():
    assert is_engine_fatal(SimulatedOOM("boom"))
    assert is_engine_fatal(RuntimeError("RESOURCE_EXHAUSTED: hbm"))
    assert is_engine_fatal(RuntimeError("Out of memory while trying"))
    assert not is_engine_fatal(RuntimeError("boom"))
    assert classify_failure(WatchdogTimeout("slow")) == "fatal"
    assert classify_failure(InjectedFault("x")) == "transient"
    assert classify_failure(CorruptOutputError("x")) == "transient"


def test_backoff_is_capped_exponential_with_jitter():
    assert backoff_delay(1, 0.1, 10.0) == pytest.approx(0.1)
    assert backoff_delay(3, 0.1, 10.0) == pytest.approx(0.4)
    assert backoff_delay(30, 0.1, 10.0) == pytest.approx(10.0)  # capped
    b = Backoff(0.1, 10.0, seed=1)
    d = b.delay(2)
    assert 0.1 <= d < 0.3               # jitter in [0.5, 1.5) of 0.2
    assert Backoff(0.1, 10.0, seed=1).delay(2) == pytest.approx(d)


def test_circuit_breaker_window_and_reset():
    cb = CircuitBreaker(threshold=3, window_s=10.0)
    assert not cb.record_fault(now=0.0)
    assert not cb.record_fault(now=1.0)
    assert cb.record_fault(now=2.0)             # 3 inside the window
    assert cb.degraded and cb.trips == 1
    cb.record_success()
    assert not cb.degraded
    # faults spread wider than the window never trip
    assert not cb.record_fault(now=100.0)
    assert not cb.record_fault(now=120.0)
    assert not cb.record_fault(now=140.0)
    assert cb.trips == 1


def test_degradation_ladder_rungs_and_cheapening():
    dg = DegradeConfig(rungs=(LadderRung(0.5, 0.5),
                              LadderRung(0.8, 0.25)))
    ladder = DegradationLadder(dg, max_queue_depth=10)
    assert ladder.rung_for(0) == 0
    assert ladder.rung_for(5) == 1
    assert ladder.rung_for(8) == 2
    # deadline headroom bumps one extra rung (clamped at the top)
    assert ladder.rung_for(5, deadline_s=0.5, batch_ema_s=0.2) == 2
    assert ladder.rung_for(8, deadline_s=0.5, batch_ema_s=0.2) == 2
    # steps scale down but never below one step per block
    assert ladder.cheapen_steps(1, DCFG, None, None, None) == 8
    assert ladder.cheapen_steps(2, DCFG, None, None, None) == 4
    assert ladder.cheapen_steps(2, DCFG, 64, 16, 2) == 16
    assert ladder.cheapen_steps(0, DCFG, 12, None, None) == 12
    # infeasible geometry passes through for the engine to reject
    assert ladder.cheapen_steps(2, DCFG, 12, 10, 8) == 12
    disabled = DegradationLadder(DegradeConfig(enabled=False), 10)
    assert disabled.rung_for(9) == 0


def test_bisect_shapes():
    assert bisect([1]) == [[1]]
    assert bisect([1, 2]) == [[1], [2]]
    assert bisect([1, 2, 3]) == [[1], [2, 3]]
    assert bisect([1, 2, 3, 4]) == [[1, 2], [3, 4]]


# --------------------------------------------------------------------------
# engine-level: the injector fires at the block grain
# --------------------------------------------------------------------------

def test_engine_block_fault_and_clean_retry(params):
    """An injected block fault aborts the attempt BEFORE results land;
    re-driving the same batch (same rng) is bit-identical to an
    uninjected decode."""
    engine = _engine(params,
                     faults=[Fault(kind="error", batch_index=0, block=1)])
    rid = engine.submit(_prompt())
    batch = engine.select_batch()
    with pytest.raises(InjectedFault):
        for _ in engine.decode_batch_blocks(batch):
            pass
    assert engine.result(rid).result is None if rid in engine.done \
        else rid not in engine.done          # no result from the failure
    # retry: fault budget spent, same batch decodes clean
    blocks = list(engine.decode_batch_blocks(batch))
    assert len(blocks) == DCFG.gen_length // DCFG.block_size
    assert engine.result(rid).status == "done"
    np.testing.assert_array_equal(engine.result(rid).result,
                                  _direct(params, _prompt()))


def test_engine_nan_fault_raises_corrupt_output(params):
    engine = _engine(params, faults=[Fault(kind="nan", block=0)])
    engine.submit(_prompt())
    batch = engine.select_batch()
    with pytest.raises(CorruptOutputError):
        for _ in engine.decode_batch_blocks(batch):
            pass
    assert engine.fault_injector.counters["nan"] == 1


# --------------------------------------------------------------------------
# scheduler-level supervision: retry, bisect, quarantine, breaker
# --------------------------------------------------------------------------

def _run(coro):
    asyncio.run(coro)


def test_transient_fault_is_retried_bit_identical(params):
    """One injected fault on the first attempt: supervision retries and
    the final tokens are BIT-IDENTICAL to a fault-free decode — plus a
    `reset` event if blocks had already streamed."""
    async def main():
        engine = _engine(params, faults=[
            Fault(kind="error", batch_index=0, block=1)])
        sched = AsyncScheduler(engine, svcfg=SVCFG)
        await sched.start()
        rid = sched.submit(_prompt())
        events = [e async for e in sched.events(rid)]
        kinds = [e["type"] for e in events]
        # block 0 streamed, fault on block 1 → reset → clean re-decode
        assert kinds == ["block", "reset", "block", "block", "done"]
        assert events[-1]["tokens"] == _direct(params, _prompt()).tolist()
        assert sched.counters["retries"] == 1
        assert sched.counters["resets"] == 1
        assert sched.counters["errors"] == 0
        assert sched.health == "ok"
        await sched.close()

    _run(main())


def test_poison_request_quarantined_cobatch_survives(params):
    """THE acceptance test: a rid-keyed persistent fault in a 4-request
    batch.  Supervision retries, bisects, and quarantines — the poison
    rid gets exactly one terminal `error` event; the three co-batched
    requests all finish bit-identical to fault-free decodes."""
    async def main():
        engine = _engine(params, max_batch=4)
        sched = AsyncScheduler(engine, svcfg=SVCFG)
        # submit FIRST so rids are known, then arm the injector before
        # starting the worker: deterministic co-batching
        rids = [sched.submit(_prompt(i)) for i in range(4)]
        poison = rids[2]
        engine.set_fault_injector(FaultInjector(
            [Fault(kind="error", rid=poison, times=None)]))
        await sched.start()
        terminals = {}
        for i, rid in enumerate(rids):
            events = [e async for e in sched.events(rid)]
            finals = [e for e in events if e.get("final")]
            assert len(finals) == 1, f"rid {rid}: {events}"
            terminals[rid] = finals[0]
        assert terminals[poison]["type"] == "error"
        assert "injected fault" in terminals[poison]["error"]
        for i, rid in enumerate(rids):
            if rid == poison:
                continue
            assert terminals[rid]["type"] == "done", terminals[rid]
            assert terminals[rid]["tokens"] == \
                _direct(params, _prompt(i)).tolist()
        assert sched.counters["quarantined"] == 1
        assert sched.counters["errors"] == 1
        assert sched.counters["requeued"] > 0
        assert sched.health == "ok"         # loop alive, breaker quiet
        m = sched.metrics()
        assert m["faults_injected"]["error"] >= 3
        await sched.close()

    _run(main())


def test_oom_trips_breaker_and_rebuilds_engine(params):
    """Two simulated OOMs (breaker_threshold=2) trip the circuit
    breaker: the engine is rebuilt through the rebuild callable, health
    reports degraded until the next clean batch, and the request that
    rode through the crashes still completes on the fresh engine."""
    async def main():
        rebuilds = []

        def make_engine(faults=()):
            return _engine(params, faults=faults)

        engine = make_engine(faults=[
            Fault(kind="oom", batch_index=0),
            Fault(kind="oom", batch_index=1)])

        def rebuild():
            rebuilds.append(1)
            return make_engine()

        sched = AsyncScheduler(engine, svcfg=SVCFG,
                               rebuild_engine=rebuild)
        await sched.start()
        rid = sched.submit(_prompt())
        degraded_seen = False
        # poll health while the worker crashes / rebuilds underneath
        for _ in range(200):
            if sched.health == "degraded":
                degraded_seen = True
                break
            await asyncio.sleep(0.01)
        terminal = await sched.result(rid)
        assert terminal["type"] == "done"
        assert terminal["tokens"] == _direct(params, _prompt()).tolist()
        assert degraded_seen
        assert rebuilds == [1]
        assert sched.engine is not engine       # actually swapped
        assert sched.counters["engine_faults"] == 2
        assert sched.counters["engine_rebuilds"] == 1
        assert sched.breaker.trips == 1
        assert sched.health == "ok"             # clean batch cleared it
        await sched.close()

    _run(main())


def test_watchdog_timeout_is_engine_fatal(params):
    """A block slower than the watchdog raises WatchdogTimeout; with no
    rebuild callable and retries exhausted the request errors out — but
    the loop survives for the next request."""
    async def main():
        engine = _engine(params, faults=[
            Fault(kind="latency", delay_s=0.6, block=0, times=None,
                  rid=0)])
        svcfg = dataclasses.replace(SVCFG, watchdog_s=0.25,
                                    max_retries=1, breaker_threshold=99)
        sched = AsyncScheduler(engine, svcfg=svcfg)
        await sched.start()
        rid = sched.submit(_prompt())
        terminal = await sched.result(rid)
        assert terminal["type"] == "error"
        assert "watchdog" in terminal["error"]
        assert sched.counters["watchdog_timeouts"] >= 1
        assert sched.counters["engine_faults"] >= 1
        ok = sched.submit(_prompt(1))
        terminal = await sched.result(ok)
        assert terminal["type"] == "done"
        await sched.close()

    _run(main())


def test_ladder_cheapens_under_pressure(params):
    """Submissions past the rung thresholds decode with scaled-down
    steps; the degraded counter records each cheapened admission."""
    async def main():
        engine = _engine(params)
        sched = AsyncScheduler(
            engine, max_queue_depth=4,
            dgcfg=DegradeConfig(rungs=(LadderRung(0.5, 0.5),)),
            svcfg=SVCFG)
        # no worker: the queue holds still while we probe admission
        rids = [sched.submit(_prompt(i)) for i in range(4)]
        assert sched.counters["degraded"] == 2      # depth 2,3 ≥ 50%
        cheapened = [engine.queue[i].dcfg.steps for i in range(4)]
        assert cheapened == [16, 16, 8, 8]
        assert sched.metrics()["ladder_rung"] == 1
        # the cheapened request still decodes (geometry stays feasible)
        await sched.start()
        for rid in rids:
            terminal = await sched.result(rid)
            assert terminal["type"] == "done"
        await sched.close()

    _run(main())


# --------------------------------------------------------------------------
# end-to-end over sockets: faults through the HTTP front end
# --------------------------------------------------------------------------

def test_server_survives_poison_request(params):
    """Fault smoke over real sockets: a poisoned rid errors, a healthy
    request right behind it completes, /healthz stays ok, /metrics
    exposes the supervision counters."""
    from repro.serving import ServerThread, ServingClient

    injector = FaultInjector([Fault(kind="error", rid=0, times=None)])

    def factory():
        return ServingEngine(params, CFG, DCFG, max_batch=4,
                             fault_injector=injector)

    router = ModelRouter(RouterConfig())
    router.register("tiny", factory)
    scfg = ServerConfig(port=0, supervisor=SVCFG)
    handle = ServerThread(router, scfg).start()
    try:
        client = ServingClient(handle.host, handle.port, max_retries=0)
        events = list(client.generate_stream(_prompt().tolist()))
        assert events[-1][0] == "error"
        assert events[-1][1]["final"] is True
        ok = client.generate(_prompt(1).tolist(), wait=True)
        assert ok["status"] == "ok"
        assert ok["tokens"] == _direct(params, _prompt(1)).tolist()
        health = client.healthz()
        assert health["ok"] is True
        assert health["status"] == "ok"
        assert health["health"]["tiny"] == "ok"
        text = client.metrics_text()
        assert 'repro_requests_quarantined_total{model="tiny"} 1' in text
        assert 'repro_faults_injected_total{model="tiny",kind="error"}' \
            in text
        assert 'repro_health_degraded{model="tiny"} 0' in text
    finally:
        handle.stop()
