"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device (the dry-run sets its own 512-device flag in-process)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


class _RecordingRegistry(dict):
    """Registry stand-in that remembers every registration made while a
    test runs, even ones the test unregisters before finishing."""

    def __init__(self, base):
        super().__init__(base)
        self.added = {}

    def __setitem__(self, key, value):
        self.added[key] = value
        super().__setitem__(key, value)


@pytest.fixture(autouse=True)
def strategy_conformance_guard(request):
    """Every strategy a test registers is conformance-checked for free.

    The fused-decode contracts (carry fixed-point across both fused
    drivers, no unsanctioned callbacks, no baked weights, no f64
    promotion — ``repro.analysis.conformance``) quantify over *future*
    strategies, so throwaway test strategies are exactly the ones that
    need checking: a test can pass end-to-end on the host driver while
    its strategy would break the ``lax.while_loop`` carry invariant in
    production.  Opt out with ``@pytest.mark.no_conformance`` (for tests
    that register deliberately broken strategies)."""
    from repro.core import strategies as S

    S._ensure_builtins()          # builtin imports must not count as new
    original = S._REGISTRY
    recording = _RecordingRegistry(original)
    S._REGISTRY = recording
    try:
        yield
    finally:
        original.clear()
        original.update(recording)
        S._REGISTRY = original
    if request.node.get_closest_marker("no_conformance"):
        return
    from repro.analysis import assert_conforms
    for name, strat in recording.added.items():
        assert_conforms(strat)
