"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device (the dry-run sets its own 512-device flag in-process)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
