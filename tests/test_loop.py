"""Device-resident fused block loop (core/loop.py): fused-vs-host parity
for every registered strategy, compile-count guarantees, the Pallas
confidence-kernel wiring, and the bucketed serving scheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DecodeConfig, get_config
from repro.core import generate, generate_cached, score_logits
from repro.core.confidence import pallas_enabled
from repro.models.model import forward, init_model
from repro.serving import ServingEngine

CFG = get_config("llada-8b").reduced()

STRATEGIES = ["random", "probability", "margin", "entropy", "eb", "wino",
              "fdm", "fdm_a"]


@pytest.fixture(scope="module")
def model():
    """Untrained tiny model — parity is about decode mechanics, not
    quality, and skipping training keeps this file fast."""
    params = init_model(jax.random.PRNGKey(0), CFG)
    model_fn = jax.jit(lambda x: forward(params, x, CFG)[0])
    return params, model_fn


def _dcfg(**over):
    base = dict(gen_length=16, block_size=8, steps=16, k=2, k1=2)
    base.update(over)
    return DecodeConfig(**base)


# --------------------------------------------------------------------------
# parity: fused while_loop ≡ host step loop, bit-for-bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_fused_host_parity(model, strategy):
    _, model_fn = model
    prompts = jnp.full((3, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy=strategy)
    out_f, s_f = generate(jax.random.PRNGKey(0), model_fn, prompts, CFG,
                          dataclasses.replace(dcfg, fused_loop=True))
    out_h, s_h = generate(jax.random.PRNGKey(0), model_fn, prompts, CFG,
                          dataclasses.replace(dcfg, fused_loop=False))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_h))
    assert s_f.steps == s_h.steps
    assert s_f.forward_equivalents == pytest.approx(s_h.forward_equivalents)
    assert not (np.asarray(out_f) == CFG.mask_token_id).any()


@pytest.mark.parametrize("strategy", ["probability", "eb", "fdm_a"])
def test_cached_fused_host_parity(model, strategy):
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy=strategy)
    out_f, s_f = generate_cached(jax.random.PRNGKey(0), params, prompts,
                                 CFG,
                                 dataclasses.replace(dcfg, fused_loop=True))
    out_h, s_h = generate_cached(jax.random.PRNGKey(0), params, prompts,
                                 CFG,
                                 dataclasses.replace(dcfg,
                                                     fused_loop=False))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_h))
    assert s_f.steps == s_h.steps
    assert s_f.forward_equivalents == pytest.approx(s_h.forward_equivalents)


# --------------------------------------------------------------------------
# compile count: one trace per strategy × shape, across blocks AND calls
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,expected_traces",
                         [("probability", 1), ("fdm", 2)])
def test_one_compilation_per_strategy_and_shape(model, strategy,
                                                expected_traces):
    """The whole decode — 2 blocks × 8 steps × 2 generate calls — must
    trace the model exactly once per distinct forward shape: (B, L) for
    every strategy, plus (K·B, L) for the foreseeing branch."""
    params, _ = model
    traces = []

    def counting_fn(x):
        traces.append(x.shape)          # side effect fires at trace time
        return forward(params, x, CFG)[0]

    prompts = jnp.full((2, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy=strategy, fused_loop=True)
    generate(jax.random.PRNGKey(0), counting_fn, prompts, CFG, dcfg)
    assert len(traces) == expected_traces, traces
    generate(jax.random.PRNGKey(1), counting_fn, prompts, CFG, dcfg)
    assert len(traces) == expected_traces, "recompiled on second call"


# --------------------------------------------------------------------------
# Pallas confidence-kernel wiring (score_logits use_kernel path)
# --------------------------------------------------------------------------

def test_pallas_flag_resolution():
    assert pallas_enabled(DecodeConfig(use_pallas_kernel=True)) is True
    assert pallas_enabled(DecodeConfig(use_pallas_kernel=False)) is False
    on_tpu = jax.default_backend() == "tpu"
    assert pallas_enabled(DecodeConfig()) is on_tpu     # auto
    assert pallas_enabled(None) is on_tpu


def test_score_logits_kernel_matches_reference(rng):
    logits = 3 * jax.random.normal(rng, (2, 5, 131))
    ref = score_logits(logits)
    fused = score_logits(logits, use_kernel=True)       # interpret on CPU
    np.testing.assert_array_equal(fused.argmax, ref.argmax)
    np.testing.assert_allclose(fused.max_prob, ref.max_prob, rtol=1e-5)
    np.testing.assert_allclose(fused.margin, ref.margin, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(fused.neg_entropy, ref.neg_entropy,
                               rtol=1e-4, atol=1e-5)


def test_kernel_on_decode_path(model):
    """use_pallas_kernel=True flows through the fused loop end-to-end."""
    _, model_fn = model
    prompts = jnp.full((1, 6), 2, jnp.int32)
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8,
                 strategy="probability", use_pallas_kernel=True)
    out_k, _ = generate(jax.random.PRNGKey(0), model_fn, prompts, CFG, dcfg)
    out_r, _ = generate(jax.random.PRNGKey(0), model_fn, prompts, CFG,
                        dataclasses.replace(dcfg, use_pallas_kernel=False))
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# --------------------------------------------------------------------------
# serving scheduler: prompt-length buckets + per-request stats
# --------------------------------------------------------------------------

def _engine(params, max_batch=4):
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8,
                 strategy="probability")
    return ServingEngine(params, CFG, dcfg, max_batch=max_batch,
                         length_bucket=8)


def test_serving_no_head_of_line_blocking(model):
    """Interleaved prompt lengths must coalesce by bucket: the old
    scheduler (consecutive equal lengths only) needed 5 batches here."""
    params, _ = model
    engine = _engine(params)
    lens = [5, 13, 5, 13, 5]
    rids = [engine.submit(np.full((l,), 3, np.int32)) for l in lens]
    steps = 0
    while engine.queue:
        engine.step()
        steps += 1
    assert steps == 2
    for rid, l in zip(rids, lens):
        req = engine.result(rid)
        assert req.result.shape == (l + 8,)
        # pad columns were sliced off; the answer region is committed
        assert not (req.result[l:] == CFG.mask_token_id).any()


def test_serving_pads_within_bucket(model):
    """Lengths 5 and 7 share the 8-ceiling bucket -> one batch."""
    params, _ = model
    engine = _engine(params)
    r1 = engine.submit(np.full((5,), 3, np.int32))
    r2 = engine.submit(np.full((7,), 3, np.int32))
    finished = engine.step()
    assert sorted(finished) == sorted([r1, r2])
    assert engine.result(r1).result.shape == (13,)
    assert engine.result(r2).result.shape == (15,)


def test_serving_per_request_stats(model):
    """Each request gets its own SampleStats, pro-rated to real batch
    members (pad replication must not inflate tokens/forwards)."""
    params, _ = model
    engine = _engine(params, max_batch=4)
    rids = [engine.submit(np.full((6,), 3, np.int32)) for _ in range(3)]
    engine.run_until_idle()
    stats = [engine.result(r).stats for r in rids]
    assert stats[0] is not stats[1] and stats[1] is not stats[2]
    for s in stats:
        assert s.tokens_generated == 8          # gen_length, not B·gen
        # batch forwards split across the 3 REAL requests (batch padded
        # to 4): 8 steps × 1 fwd / 3
        assert s.forward_equivalents == pytest.approx(8 / 3)
