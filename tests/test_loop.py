"""Device-resident decode loops (core/loop.py): three-driver parity
(host step loop / per-block fused / whole-request fused) for every
registered strategy, compile-count guarantees, the Pallas
confidence-kernel wiring, and the bucketed serving scheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DecodeConfig, get_config
from repro.core import Decoder, score_logits
from repro.core.confidence import pallas_enabled
from repro.models.model import forward, init_model
from repro.serving import ServingEngine

CFG = get_config("llada-8b").reduced()

STRATEGIES = ["random", "probability", "margin", "entropy", "eb", "wino",
              "fdm", "fdm_a", "wino_r", "extrapolate"]

# the three decode drivers (DecodeConfig overrides)
DRIVERS = {
    "host": dict(fused_loop=False),
    "block": dict(fused_loop=True, fused_blocks=False),
    "request": dict(fused_loop=True, fused_blocks=True),
}


@pytest.fixture(scope="module")
def model():
    """Untrained tiny model — parity is about decode mechanics, not
    quality, and skipping training keeps this file fast."""
    params = init_model(jax.random.PRNGKey(0), CFG)
    model_fn = jax.jit(lambda x: forward(params, x, CFG)[0])
    return params, model_fn


def _dcfg(**over):
    base = dict(gen_length=16, block_size=8, steps=16, k=2, k1=2)
    base.update(over)
    return DecodeConfig(**base)


# --------------------------------------------------------------------------
# parity: host step loop ≡ per-block fused ≡ whole-request fused, bit-for-bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_three_driver_parity(model, strategy):
    """All 10 strategies — the carry-ful ones included — must produce
    bit-identical tokens, step counts, and forward counts under the host
    loop, the per-block fused driver, and the single-dispatch
    whole-request driver."""
    _, model_fn = model
    prompts = jnp.full((3, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy=strategy)
    runs = {}
    for name, over in DRIVERS.items():
        runs[name] = Decoder(model_fn, CFG,
                             dataclasses.replace(dcfg, **over)).generate(
            jax.random.PRNGKey(0), prompts)
    out_ref, s_ref = runs["host"]
    for name in ("block", "request"):
        out, s = runs[name]
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref),
                                      err_msg=f"{strategy}/{name}")
        assert s.steps == s_ref.steps, (strategy, name)
        assert s.forward_equivalents == \
            pytest.approx(s_ref.forward_equivalents), (strategy, name)
        assert s.phase_counts == s_ref.phase_counts, (strategy, name)
    assert not (np.asarray(out_ref) == CFG.mask_token_id).any()


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_remainder_steps_are_not_dropped(model, driver):
    """steps=10 over 4 blocks used to floor to 2 steps/block and quietly
    run 8; the schedule now spreads the remainder ([3,3,2,2] budgets,
    commit widths summing to block_size inside each) so the request runs
    exactly its configured step budget — under every driver."""
    params, _ = model
    from repro.core import Decoder
    prompts = jnp.full((2, 6), 2, jnp.int32)
    dcfg = _dcfg(gen_length=16, block_size=4, steps=10,
                 strategy="probability", **DRIVERS[driver])
    out, stats = Decoder(params, CFG, dcfg).generate(jax.random.PRNGKey(0),
                                                     prompts)
    assert stats.steps == 10
    assert not (np.asarray(out) == CFG.mask_token_id).any()


def test_remainder_steps_three_driver_parity(model):
    """The remainder schedule must stay bit-identical across drivers."""
    params, _ = model
    from repro.core import Decoder
    prompts = jnp.full((2, 6), 2, jnp.int32)
    outs = []
    for over in DRIVERS.values():
        dcfg = _dcfg(gen_length=16, block_size=4, steps=10,
                     strategy="probability", **over)
        out, stats = Decoder(params, CFG, dcfg).generate(
            jax.random.PRNGKey(0), prompts)
        outs.append((np.asarray(out), stats.steps))
    for out, steps in outs[1:]:
        np.testing.assert_array_equal(out, outs[0][0])
        assert steps == outs[0][1]


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_fdm_a_phase_counts_sum_to_steps(model, driver):
    """The (explore, accel, local_only, balance) histogram is populated
    from the device-side carry; with batch 1 each step lands in exactly
    one phase, so the counts sum to stats.steps — under every driver."""
    params, _ = model
    from repro.core import Decoder
    prompts = jnp.full((1, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy="fdm_a", **DRIVERS[driver])
    _, stats = Decoder(params, CFG, dcfg).generate(jax.random.PRNGKey(0),
                                                   prompts)
    assert set(stats.phase_counts) == \
        {"explore", "accel", "local_only", "balance"}
    assert sum(stats.phase_counts.values()) == stats.steps
    assert stats.steps > 0


def test_infeasible_step_budget_raises(model):
    """steps < num_blocks cannot be honoured (≥1 step per block): a clear
    error beats silently running more steps than configured."""
    params, _ = model
    from repro.core import Decoder
    dcfg = _dcfg(gen_length=16, block_size=4, steps=2)
    with pytest.raises(ValueError, match="infeasible"):
        Decoder(params, CFG, dcfg).generate(jax.random.PRNGKey(0),
                                            jnp.full((1, 4), 2, jnp.int32))


def test_serving_phase_counts_exclude_pad_replicas(model):
    """Per-request phase histograms are per-example averages over the
    padded batch, so replica rows don't inflate them and the
    sum == steps invariant holds per request."""
    params, _ = model
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8, strategy="fdm_a")
    engine = ServingEngine(params, CFG, dcfg, max_batch=4, length_bucket=8)
    rids = [engine.submit(np.full((6,), 3, np.int32)) for _ in range(3)]
    engine.run_until_idle()               # batch of 3 real + 1 replica
    for rid in rids:
        s = engine.result(rid).stats
        assert sum(s.phase_counts.values()) == pytest.approx(s.steps)


def test_fdm_a_phase_counts_cached_path(model):
    params, _ = model
    prompts = jnp.full((1, 6), 2, jnp.int32)
    _, stats = Decoder(params, CFG,
                       _dcfg(strategy="fdm_a",
                             cache_policy="prefix")).generate(
        jax.random.PRNGKey(0), prompts)
    assert sum(stats.phase_counts.values()) == stats.steps


@pytest.mark.parametrize("strategy", ["probability", "eb", "fdm_a",
                                      "wino_r", "extrapolate"])
def test_cached_fused_host_parity(model, strategy):
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy=strategy, cache_policy="prefix")
    out_f, s_f = Decoder(params, CFG,
                         dataclasses.replace(dcfg, fused_loop=True)
                         ).generate(jax.random.PRNGKey(0), prompts)
    out_h, s_h = Decoder(params, CFG,
                         dataclasses.replace(dcfg, fused_loop=False)
                         ).generate(jax.random.PRNGKey(0), prompts)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_h))
    assert s_f.steps == s_h.steps
    assert s_f.forward_equivalents == pytest.approx(s_h.forward_equivalents)


# --------------------------------------------------------------------------
# compile count: one trace per strategy × shape, across blocks AND calls
# --------------------------------------------------------------------------

@pytest.mark.parametrize("driver", ["block", "request"])
@pytest.mark.parametrize("strategy,expected_traces",
                         [("probability", 1), ("fdm", 2)])
def test_one_compilation_per_strategy_and_shape(model, strategy,
                                                expected_traces, driver):
    """The whole decode — 2 blocks × 8 steps × 2 generate calls — must
    trace the model exactly once per distinct forward shape: (B, L) for
    every strategy, plus (K·B, L) for the foreseeing branch.  Holds for
    both fused drivers (per-block and single-dispatch whole-request).
    Runs inside a fresh ``decode_cache_scope`` so the count cannot depend
    on what earlier tests left in the process-wide runner cache."""
    from repro.core import decode_cache_scope
    params, _ = model
    traces = []

    def counting_fn(x):
        traces.append(x.shape)          # side effect fires at trace time
        return forward(params, x, CFG)[0]

    prompts = jnp.full((2, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy=strategy, **DRIVERS[driver])
    with decode_cache_scope():
        Decoder(counting_fn, CFG, dcfg).generate(jax.random.PRNGKey(0),
                                                 prompts)
        assert len(traces) == expected_traces, traces
        Decoder(counting_fn, CFG, dcfg).generate(jax.random.PRNGKey(1),
                                                 prompts)
        assert len(traces) == expected_traces, "recompiled on second call"


# --------------------------------------------------------------------------
# Pallas confidence-kernel wiring (score_logits use_kernel path)
# --------------------------------------------------------------------------

def test_pallas_flag_resolution():
    assert pallas_enabled(DecodeConfig(use_pallas_kernel=True)) is True
    assert pallas_enabled(DecodeConfig(use_pallas_kernel=False)) is False
    on_tpu = jax.default_backend() == "tpu"
    assert pallas_enabled(DecodeConfig()) is on_tpu     # auto
    assert pallas_enabled(None) is on_tpu


def test_score_logits_kernel_matches_reference(rng):
    logits = 3 * jax.random.normal(rng, (2, 5, 131))
    ref = score_logits(logits)
    fused = score_logits(logits, use_kernel=True)       # interpret on CPU
    np.testing.assert_array_equal(fused.argmax, ref.argmax)
    np.testing.assert_allclose(fused.max_prob, ref.max_prob, rtol=1e-5)
    np.testing.assert_allclose(fused.margin, ref.margin, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(fused.neg_entropy, ref.neg_entropy,
                               rtol=1e-4, atol=1e-5)


def test_kernel_on_decode_path(model):
    """use_pallas_kernel=True flows through the fused loop end-to-end."""
    _, model_fn = model
    prompts = jnp.full((1, 6), 2, jnp.int32)
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8,
                 strategy="probability", use_pallas_kernel=True)
    out_k, _ = Decoder(model_fn, CFG, dcfg).generate(jax.random.PRNGKey(0),
                                                     prompts)
    out_r, _ = Decoder(model_fn, CFG,
                       dataclasses.replace(dcfg, use_pallas_kernel=False)
                       ).generate(jax.random.PRNGKey(0), prompts)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# --------------------------------------------------------------------------
# serving scheduler: prompt-length buckets + per-request stats
# --------------------------------------------------------------------------

def _engine(params, max_batch=4):
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8,
                 strategy="probability")
    return ServingEngine(params, CFG, dcfg, max_batch=max_batch,
                         length_bucket=8)


def test_serving_no_head_of_line_blocking(model):
    """Interleaved prompt lengths must coalesce by bucket: the old
    scheduler (consecutive equal lengths only) needed 5 batches here."""
    params, _ = model
    engine = _engine(params)
    lens = [5, 13, 5, 13, 5]
    rids = [engine.submit(np.full((l,), 3, np.int32)) for l in lens]
    steps = 0
    while engine.queue:
        engine.step()
        steps += 1
    assert steps == 2
    for rid, l in zip(rids, lens):
        req = engine.result(rid)
        assert req.result.shape == (l + 8,)
        # pad columns were sliced off; the answer region is committed
        assert not (req.result[l:] == CFG.mask_token_id).any()


def test_serving_pads_within_bucket(model):
    """Lengths 5 and 7 share the 8-ceiling bucket -> one batch."""
    params, _ = model
    engine = _engine(params)
    r1 = engine.submit(np.full((5,), 3, np.int32))
    r2 = engine.submit(np.full((7,), 3, np.int32))
    finished = engine.step()
    assert sorted(finished) == sorted([r1, r2])
    assert engine.result(r1).result.shape == (13,)
    assert engine.result(r2).result.shape == (15,)


def test_serving_per_request_stats(model):
    """Each request gets its own SampleStats, pro-rated to real batch
    members (pad replication must not inflate tokens/forwards)."""
    params, _ = model
    engine = _engine(params, max_batch=4)
    rids = [engine.submit(np.full((6,), 3, np.int32)) for _ in range(3)]
    engine.run_until_idle()
    stats = [engine.result(r).stats for r in rids]
    assert stats[0] is not stats[1] and stats[1] is not stats[2]
    for s in stats:
        assert s.tokens_generated == 8          # gen_length, not B·gen
        # batch forwards split across the 3 REAL requests (batch padded
        # to 4): 8 steps × 1 fwd / 3
        assert s.forward_equivalents == pytest.approx(8 / 3)
        # wall time pro-rated the same way, so the derived rates are
        # consistent: tps = the batch's aggregate decode throughput and
        # tokens_per_forward = tokens / (batch forwards / real) — the
        # seed pro-rated forwards only, leaving tps low by a factor of 3
        assert s.wall_time == pytest.approx(stats[0].wall_time)
        assert s.wall_time > 0
        assert s.tps == pytest.approx(3 * 8 / (3 * s.wall_time))
        assert s.steps == stats[0].steps        # true batch step count


def test_serving_summary_counts_real_requests_only(model):
    """summary() throughput/forward accounting must exclude the
    pad-replica rows: 3 real requests in a max_batch=4 batch report
    3 × (8 steps / 3) = 8 forward-equivalents total, not 8 × 4/3."""
    params, _ = model
    engine = _engine(params, max_batch=4)
    for _ in range(3):
        engine.submit(np.full((6,), 3, np.int32))
    engine.run_until_idle()
    summ = engine.summary()
    assert summ["requests"] == 3
    assert summ["forward_equivalents"] == pytest.approx(8.0)
    # decode_tps aggregates the pro-rated shares back to batch throughput
    wall = engine.result(0).stats.wall_time
    assert summ["decode_tps"] == pytest.approx(3 * 8 / (3 * wall))
