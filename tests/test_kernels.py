"""Per-kernel allclose validation against the pure-jnp oracles.

Shape/dtype sweeps per the assignment contract: every Pallas kernel is
executed in interpret mode (Python emulation on CPU) and compared against
``ref.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.confidence import ROWS, VTILE, confidence_fused
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (attention_ref, confidence_ref,
                               selective_scan_ref)
from repro.kernels.selective_scan import selective_scan

CONF_SHAPES = [
    ((4, 7), 1000),        # ragged rows and vocab
    ((2, 3), VTILE + 3),   # one lane over a tile boundary
    ((5,), 2 * VTILE),     # exact tiles
    ((2, 2), 130),         # single partial tile
    ((ROWS + 1, 2), 513),  # row padding
]


@pytest.mark.parametrize("shape,vocab", CONF_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_confidence_kernel_matches_ref(shape, vocab, dtype):
    rng = jax.random.PRNGKey(hash((shape, vocab)) % 2**31)
    logits = (5 * jax.random.normal(rng, shape + (vocab,))).astype(dtype)
    a, p, m, e = confidence_fused(logits)
    ra, rp, rm, re = confidence_ref(logits)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ra))
    np.testing.assert_allclose(p, rp, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(m, rm, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(e, re, rtol=2e-3, atol=2e-4)


def test_confidence_kernel_duplicate_max():
    """Ties for the top logit must give margin exactly 0."""
    logits = jnp.zeros((1, 8))  # all equal
    _, p, m, _ = confidence_fused(logits)
    np.testing.assert_allclose(m[0], 0.0, atol=1e-6)
    np.testing.assert_allclose(p[0], 1.0 / 8, rtol=1e-5)


def test_confidence_kernel_extreme_logits():
    """Large-magnitude logits: online softmax must not overflow."""
    logits = jnp.array([[1e4, -1e4, 0.0, 5.0] * 200])
    a, p, m, e = confidence_fused(logits)
    ra, rp, rm, re = confidence_ref(logits)
    assert int(a[0]) == int(ra[0])
    np.testing.assert_allclose(p, rp, rtol=1e-5)
    assert np.isfinite(np.asarray(e)).all()


ATTN_SHAPES = [
    (2, 100, 100, 2, 64, 0),
    (1, 256, 256, 1, 128, 0),
    (1, 300, 300, 2, 64, 50),     # banded + ragged
    (2, 128, 256, 1, 32, 0),      # cross lengths
    (1, 257, 257, 1, 64, 128),    # band wider than one tile
]


@pytest.mark.parametrize("b,lq,lk,h,d,w", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, lq, lk, h, d, w, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, lq, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, lk, h, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, lk, h, d)).astype(dtype)
    out = flash_attention(q, k, v, window=w)
    ref = attention_ref(q, k, v, window=w)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


SCAN_SHAPES = [
    (2, 300, 130, 16),    # ragged time + channel tiles
    (1, 256, 128, 8),     # exact tiles
    (2, 100, 64, 16),     # single partial tile
]


@pytest.mark.parametrize("b,l,di,n", SCAN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_kernel_matches_ref(b, l, di, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(l + di), 4)
    x = jax.random.normal(ks[0], (b, l, di)).astype(dtype)
    delta = jax.nn.softplus(
        jax.random.normal(ks[1], (b, l, di)) - 2).astype(dtype)
    bs = jax.random.normal(ks[2], (b, l, n)).astype(dtype)
    cs = jax.random.normal(ks[3], (b, l, n)).astype(dtype)
    a_log = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                    )[None].repeat(di, 0)
    y = selective_scan(x, delta, bs, cs, a_log)
    yr = selective_scan_ref(x, delta, bs, cs, a_log)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


def test_selective_scan_state_carries_across_tiles():
    """A constant drive with slow decay must accumulate monotonically far
    beyond one T_TILE boundary (state carried in scratch, not reset)."""
    b, l, di, n = 1, 600, 64, 4
    x = jnp.ones((b, l, di))
    delta = jnp.full((b, l, di), 0.01)
    bs = jnp.ones((b, l, n))
    cs = jnp.ones((b, l, n))
    a_log = jnp.full((di, n), -3.0)   # A ≈ -0.05: slow decay
    y = selective_scan(x, delta, bs, cs, a_log)
    assert float(y[0, 599, 0]) > float(y[0, 100, 0]) > float(y[0, 5, 0])


def test_flash_attention_band_excludes_far_tokens():
    """With window=1 every query attends only to itself."""
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    q = jax.random.normal(ks[0], (1, 140, 1, 16))
    v = jax.random.normal(ks[1], (1, 140, 1, 16))
    out = flash_attention(q, q, v, window=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v),
                               rtol=1e-5, atol=1e-5)
