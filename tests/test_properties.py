"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis is not installed in this container (see ROADMAP)")
from hypothesis import given, settings, strategies as st   # noqa: E402

from repro.configs import DecodeConfig, get_config
from repro.core import commit_topn, rank_desc, score_logits
from repro.core.confidence import global_confidence
from repro.core.fdm import fdm_select
from repro.core.fdm_a import fdm_a_plan
from repro.kernels.confidence import confidence_fused
from repro.kernels.ref import confidence_ref

CFG = get_config("llada-8b").reduced()
SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def logit_arrays(draw, max_rows=4, max_vocab=600):
    rows = draw(st.integers(1, max_rows))
    vocab = draw(st.integers(2, max_vocab))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(0.1, 30.0))
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (rows, vocab))


@given(logit_arrays())
@settings(**SETTINGS)
def test_scores_are_valid_probabilities(logits):
    s = score_logits(logits[None])
    assert (s.max_prob > 0).all() and (s.max_prob <= 1 + 1e-6).all()
    assert (s.margin >= -1e-6).all()
    assert (s.margin <= s.max_prob + 1e-6).all()
    # negative entropy bounded by [-log V, 0]
    v = logits.shape[-1]
    assert (s.neg_entropy <= 1e-5).all()
    assert (s.neg_entropy >= -np.log(v) - 1e-4).all()


@given(logit_arrays(max_rows=3, max_vocab=900))
@settings(**SETTINGS)
def test_fused_kernel_equals_reference_everywhere(logits):
    a, p, m, e = confidence_fused(logits)
    ra, rp, rm, re = confidence_ref(logits)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ra))
    np.testing.assert_allclose(p, rp, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(m, rm, rtol=1e-4, atol=1e-6)
    # neg-entropy: the online u = Σ l·exp(l−m) accumulator cancels against
    # logZ near H≈0, so the absolute floor dominates the comparison there
    np.testing.assert_allclose(e, re, rtol=1e-3, atol=5e-4)


@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
@settings(**SETTINGS)
def test_commit_topn_commits_min_n_eligible(seed, n):
    rng = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(rng)
    conf = jax.random.uniform(k1, (2, 12))
    eligible = jax.random.bernoulli(k2, 0.6, (2, 12))
    x = jnp.full((2, 12), -1, jnp.int32)
    cand = jnp.zeros((2, 12), jnp.int32)
    out = commit_topn(x, conf, cand, eligible, n)
    committed = (out != -1)
    # commits exactly min(n, #eligible) per row, only at eligible slots
    want = jnp.minimum(n, eligible.sum(-1))
    np.testing.assert_array_equal(committed.sum(-1), want)
    assert not (committed & ~eligible).any()


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_rank_desc_is_permutation(seed):
    conf = jax.random.uniform(jax.random.PRNGKey(seed), (3, 9))
    r = rank_desc(conf)
    np.testing.assert_array_equal(np.sort(np.asarray(r), -1),
                                  np.tile(np.arange(9), (3, 1)))


@given(st.integers(0, 2**31 - 1), st.integers(1, 4),
       st.floats(0.0, 0.99))
@settings(**SETTINGS)
def test_fdm_progress_guarantee(seed, k, gamma):
    """FDM must commit at least one token per step whatever γ/K —
    otherwise the sampler would deadlock."""
    rng = jax.random.PRNGKey(seed)
    logits = 2 * jax.random.normal(rng, (2, 8, CFG.vocab_size))
    x = jnp.full((2, 8), CFG.mask_token_id, jnp.int32)
    def model(q):
        return 2 * jax.random.normal(
            jax.random.PRNGKey(0), (q.shape[0], 8, CFG.vocab_size))
    new_x, _ = fdm_select(x, logits, jnp.ones((2, 8), bool), model, CFG,
                          k=k, gamma=gamma, n=1)
    assert ((new_x != CFG.mask_token_id).sum(-1) >= 1).all()


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_fdm_a_plan_phase_partition(seed):
    """Every example lands in exactly one of the four phases."""
    dcfg = DecodeConfig()
    logits = 3 * jax.random.normal(jax.random.PRNGKey(seed), (4, 10, 64))
    active = jnp.ones((4, 10), bool)
    _, n, gamma, need, (explore, accel, local, balance) = \
        fdm_a_plan(logits, active, dcfg)
    one_hot = (explore.astype(int) + accel.astype(int)
               + local.astype(int) + balance.astype(int))
    np.testing.assert_array_equal(one_hot, np.ones(4, int))
    assert (n >= 1).all()
    assert (n <= dcfg.n_max).all()


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_global_confidence_monotone_in_masked_set(seed):
    """Adding positions to the masked set can only lower C_global
    (each position contributes a non-positive negative entropy)."""
    logits = jax.random.normal(jax.random.PRNGKey(seed), (1, 6, 32))
    small = jnp.array([[True, False, False, True, False, False]])
    big = small | jnp.array([[False, True, False, False, True, False]])
    assert float(global_confidence(logits, big)[0]) <= \
        float(global_confidence(logits, small)[0]) + 1e-6
