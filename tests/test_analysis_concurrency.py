"""Concurrency-grain rules of ``repro.analysis`` (ANA2xx): every rule
fires on a seeded bug snippet and stays quiet on the closest clean
variant; the live serving stack passes the grain (with the guarded
emitter recognised, so the exactly-one-terminal invariant is proven over
every emission site in scheduler.py); and the ``_inflight`` fix keeps
its set identity stable across a full request lifecycle."""
import asyncio
import os
import textwrap

import numpy as np
import pytest

from repro.analysis import EVENT_PROTOCOL, analyze_concurrency
from repro.analysis.concpass import _guarded_emitters
from repro.analysis.astpass import ModuleModel
from repro.analysis.findings import RULES
from repro.analysis.suppressions import (apply_suppressions,
                                         scan_suppressions)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(src, rule=None):
    fs = analyze_concurrency("snippet.py", textwrap.dedent(src))
    return [f for f in fs if rule is None or f.rule == rule]


# --------------------------------------------------------------------------
# ANA201 — cross-thread access to loop-affine state
# --------------------------------------------------------------------------

THREAD_ENTRY_READER = """
    import asyncio

    class Sched:
        def __init__(self):
            self._loop = None
            self._inflight: set = set()

        def shutdown_nowait(self):
            if self._loop is not None:
                self._loop.call_soon_threadsafe(self.shutdown_nowait)
                return
            for rid in self._inflight:
                print(rid)

        async def _run(self):
            self._inflight = %s
"""


def test_loop_side_container_rebind_fires():
    # the exact scheduler.py:401 shape: worker rebinds the set that a
    # thread-entry method iterates from foreign threads
    fs = run(THREAD_ENTRY_READER % "set()", "ANA201")
    assert len(fs) == 1 and "_inflight" in fs[0].message
    assert "shutdown_nowait" in fs[0].message


def test_in_place_mutation_is_clean():
    src = THREAD_ENTRY_READER.replace("self._inflight = %s",
                                      "self._inflight.clear()")
    assert run(src, "ANA201") == []


def test_foreign_side_rebind_fires():
    fs = run("""
        class Worker:
            def __init__(self):
                self.results = []

            async def go(self, loop):
                await loop.run_in_executor(None, self._work)
                return self.results

            def _work(self):
                self.results = []
    """, "ANA201")
    assert len(fs) == 1 and "foreign-thread" in fs[0].message


def test_foreign_augassign_fires():
    fs = run("""
        class Worker:
            def __init__(self):
                self.count = 0

            async def go(self, loop):
                await loop.run_in_executor(None, self._work)
                return self.count

            def _work(self):
                self.count += 1
    """, "ANA201")
    assert len(fs) == 1 and "non-atomic" in fs[0].message


def test_no_foreign_context_is_clean():
    # same rebind, but nothing ever leaves the loop: single-threaded
    # attribute churn is the engine's normal idiom
    assert run("""
        class Engine:
            def __init__(self):
                self.queue = []

            def select(self):
                rest = self.queue[1:]
                self.queue = rest
    """, "ANA201") == []


# --------------------------------------------------------------------------
# ANA202 — await-spanning read-modify-write
# --------------------------------------------------------------------------

def test_await_spanning_rmw_fires():
    # the PR 6 race shape: read the handle, await it, then null it out
    fs = run("""
        import asyncio

        class Sched:
            async def start(self):
                self._task = asyncio.create_task(self.run())
                return self._task

            async def close(self):
                if self._task is not None:
                    await self._task
                    self._task = None
    """, "ANA202")
    assert len(fs) == 1 and "_task" in fs[0].message
    assert "close" in fs[0].message


def test_claim_then_act_is_clean():
    assert run("""
        import asyncio

        class Sched:
            async def start(self):
                self._task = asyncio.create_task(self.run())
                return self._task

            async def close(self):
                task, self._task = self._task, None
                if task is not None:
                    await task
    """, "ANA202") == []


def test_single_writer_attribute_is_clean():
    # _loop has no second writer: no other task can interleave a
    # conflicting write, so the post-await write cannot go stale
    assert run("""
        import asyncio

        class Sched:
            async def start(self):
                await asyncio.sleep(0)
                if self._loop is None:
                    self._loop = asyncio.get_running_loop()
    """, "ANA202") == []


def test_lock_guarded_rmw_is_clean():
    assert run("""
        import asyncio

        class Router:
            def __init__(self):
                self._lock = asyncio.Lock()

            async def build(self, name):
                async with self._lock:
                    cur = self._engines
                    await asyncio.sleep(0)
                    self._engines = cur + [name]

            async def evict(self):
                async with self._lock:
                    self._engines = []
    """, "ANA202") == []


def test_keyed_store_after_await_is_clean():
    # self.d[k] = v re-reads the container at the write site — only a
    # full rebind can publish a stale value
    assert run("""
        import asyncio

        class Sched:
            async def a(self, rid, ev):
                n = len(self._streams)
                await asyncio.sleep(0)
                self._streams[rid] = ev
                return n

            def b(self, rid, ev):
                self._streams[rid] = ev
    """, "ANA202") == []


# --------------------------------------------------------------------------
# ANA203 — lock discipline
# --------------------------------------------------------------------------

def test_asyncio_lock_on_foreign_thread_fires():
    fs = run("""
        import asyncio

        class Server:
            def __init__(self):
                self._build_lock = asyncio.Lock()

            async def go(self, loop):
                await loop.run_in_executor(None, self._build)

            def _build(self):
                with self._build_lock:
                    pass
    """, "ANA203")
    assert len(fs) == 1 and "loop-affine" in fs[0].message


def test_async_with_on_threading_lock_fires():
    fs = run("""
        import threading

        class Server:
            def __init__(self):
                self._lock = threading.Lock()

            async def go(self):
                async with self._lock:
                    pass
    """, "ANA203")
    assert len(fs) == 1 and "no async protocol" in fs[0].message


def test_threading_lock_across_await_fires():
    fs = run("""
        import asyncio
        import threading

        class Server:
            def __init__(self):
                self._lock = threading.Lock()

            async def go(self):
                with self._lock:
                    await asyncio.sleep(0)
    """, "ANA203")
    assert len(fs) == 1 and "across an await" in fs[0].message


def test_mixed_lock_discipline_fires():
    fs = run("""
        import threading

        class Stats:
            def __init__(self):
                self._lock = threading.Lock()
                self.total = 0

            def add(self, n):
                with self._lock:
                    self.total = self.total + n

            def reset(self):
                self.total = 0
    """, "ANA203")
    assert len(fs) == 1 and "mixed discipline" in fs[0].message
    assert "reset" in fs[0].message


def test_consistent_lock_discipline_is_clean():
    assert run("""
        import threading

        class Stats:
            def __init__(self):
                self._lock = threading.Lock()
                self.total = 0

            def add(self, n):
                with self._lock:
                    self.total = self.total + n

            def reset(self):
                with self._lock:
                    self.total = 0
    """, "ANA203") == []


# --------------------------------------------------------------------------
# ANA204 — task/future lifecycle
# --------------------------------------------------------------------------

def test_dropped_create_task_fires():
    fs = run("""
        import asyncio

        async def kick(handler):
            asyncio.create_task(handler())
    """, "ANA204")
    assert len(fs) == 1 and "dropped" in fs[0].message


def test_kept_task_handle_is_clean():
    assert run("""
        import asyncio

        async def kick(handler):
            t = asyncio.create_task(handler())
            await t
    """, "ANA204") == []


def test_bare_executor_future_under_wait_for_fires():
    fs = run("""
        import asyncio

        async def drive(loop, work, timeout):
            fut = loop.run_in_executor(None, work)
            return await asyncio.wait_for(fut, timeout)
    """, "ANA204")
    assert len(fs) == 1 and "shield" in fs[0].message


def test_shielded_executor_future_is_clean():
    # the scheduler watchdog idiom
    assert run("""
        import asyncio

        async def drive(loop, work, timeout):
            fut = loop.run_in_executor(None, work)
            try:
                return await asyncio.wait_for(asyncio.shield(fut),
                                              timeout)
            except asyncio.TimeoutError:
                return await fut
    """, "ANA204") == []


# --------------------------------------------------------------------------
# ANA205 — event-protocol state machine
# --------------------------------------------------------------------------

GUARDED = """
    class Sched:
        def _emit(self, stream, event):
            if stream.finished:
                return
            stream.emit(event)

        def go(self, stream, rid):
            self._emit(stream, %s)
"""


def test_terminal_without_final_fires():
    fs = run(GUARDED % '{"type": "done", "rid": rid}', "ANA205")
    assert len(fs) == 1 and "without a literal" in fs[0].message


def test_nonterminal_with_final_fires():
    fs = run(GUARDED % '{"type": "block", "rid": rid, "final": True}',
             "ANA205")
    assert len(fs) == 1 and "terminate the stream early" in fs[0].message


def test_unknown_event_type_fires():
    fs = run(GUARDED % '{"type": "finished", "rid": rid, "final": True}',
             "ANA205")
    assert len(fs) == 1 and "'finished'" in fs[0].message


def test_unresolvable_payload_is_a_proof_hole():
    fs = run("""
        class Sched:
            def _emit(self, stream, event):
                if stream.finished:
                    return
                stream.emit(event)

            def go(self, stream, builder):
                self._emit(stream, builder())
                done = {"type": "done", "final": True}
    """, "ANA205")
    assert len(fs) == 1 and "cannot be resolved" in fs[0].message


def test_direct_emit_bypassing_guard_fires():
    # the pre-fix shutdown_nowait shape: raw stream.emit with no
    # finished-guard can double-terminate a stream
    fs = run("""
        class Sched:
            def _emit(self, stream, event):
                if stream.finished:
                    return
                stream.emit(event)

            def shutdown(self, streams):
                for rid, stream in streams.items():
                    stream.emit({"type": "shutdown", "rid": rid,
                                 "final": True})
    """, "ANA205")
    assert len(fs) == 1 and "bypassing" in fs[0].message


def test_helper_resolved_payload_is_checked():
    # the scheduler's _done_event idiom: the payload is built by a
    # class-local helper returning a dict literal — still checked
    fs = run("""
        class Sched:
            @staticmethod
            def _done_event(rid):
                return {"type": "done", "rid": rid}

            def _emit(self, stream, event):
                if stream.finished:
                    return
                stream.emit(event)

            def go(self, stream, rid):
                self._emit(stream, self._done_event(rid))
    """, "ANA205")
    assert len(fs) == 1 and "without a literal" in fs[0].message


def test_guarded_emitter_with_valid_events_is_clean():
    assert run(GUARDED % ('{"type": "done", "rid": rid, '
                          '"final": True}'), "ANA205") == []


def test_module_without_protocol_dicts_is_exempt():
    # `.emit()` on a logging handler in a module that never builds
    # lifecycle events is not an emission site
    assert run("""
        def flush(handler, record):
            handler.emit(record)
    """, "ANA205") == []


# --------------------------------------------------------------------------
# the live serving stack under the grain
# --------------------------------------------------------------------------

def _live(relpath):
    path = os.path.join(REPO, relpath)
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    findings = analyze_concurrency(relpath, source)
    sups, problems = scan_suppressions(relpath, source)
    active, _ = apply_suppressions(findings, {relpath: sups})
    return active + problems, source


@pytest.mark.parametrize("relpath", [
    "src/repro/serving/scheduler.py",
    "src/repro/serving/server.py",
    "src/repro/serving/engine.py",
    "src/repro/serving/router.py",
    "src/repro/launch/serve.py",
])
def test_live_serving_stack_passes_concurrency_grain(relpath):
    active, _ = _live(relpath)
    assert active == [], [f.message for f in active]


def test_scheduler_emission_sites_prove_single_terminal():
    """The exactly-one-terminal invariant, statically: scheduler.py has
    exactly one guarded emitter (`_emit`, the finished-checking choke
    point) and zero ANA205 findings — i.e. every emission site resolves
    to a spec-conformant payload and every raw ``.emit`` goes through
    the guard."""
    relpath = "src/repro/serving/scheduler.py"
    active, source = _live(relpath)
    assert [f for f in active if f.rule == "ANA205"] == []
    mod = ModuleModel(relpath, source)
    assert _guarded_emitters(mod) == {"AsyncScheduler._emit"}
    # the spec itself covers the full terminal vocabulary the scheduler
    # emits (fault_smoke.py asserts the same set dynamically)
    assert EVENT_PROTOCOL["terminal"] == {"done", "cancelled", "expired",
                                          "error", "shutdown"}


def test_every_conc_rule_has_catalog_entry():
    seen = {f.rule for f in run("""
        import asyncio
        import threading

        class Sched:
            def __init__(self):
                self._loop = None
                self._alock = asyncio.Lock()
                self._inflight = set()

            def shutdown_nowait(self):
                if self._loop is not None:
                    self._loop.call_soon_threadsafe(self.shutdown_nowait)
                    return
                for rid in self._inflight:
                    print(rid)
                with self._alock:
                    pass

            async def start(self):
                self._task = asyncio.create_task(self.runner())
                asyncio.create_task(self.runner())

            async def runner(self):
                self._inflight = set()

            async def close(self, loop, work):
                fut = loop.run_in_executor(None, work)
                await asyncio.wait_for(fut, 1.0)
                if self._task is not None:
                    await self._task
                    self._task = None

            def _emit(self, stream, event):
                if stream.finished:
                    return
                stream.emit(event)

            def stamp(self, stream, rid):
                self._emit(stream, {"type": "done", "rid": rid})
    """)}
    assert seen == {"ANA201", "ANA202", "ANA203", "ANA204", "ANA205"}
    assert seen <= set(RULES)


def test_conc_findings_honor_suppressions():
    src = textwrap.dedent("""
        import asyncio

        async def kick(handler):
            asyncio.create_task(handler())  # repro-lint: ignore[ANA204] -- smoke helper, loop outlives it
    """)
    sups, problems = scan_suppressions("snippet.py", src)
    assert problems == []
    active, suppressed = apply_suppressions(
        analyze_concurrency("snippet.py", src), {"snippet.py": sups})
    assert active == []
    assert len(suppressed) == 1
    assert suppressed[0].suppressed == "smoke helper, loop outlives it"


# --------------------------------------------------------------------------
# the _inflight regression, behaviorally
# --------------------------------------------------------------------------

def test_inflight_set_identity_survives_request_lifecycle():
    """The ANA201 fix, observed at runtime: the set object
    ``shutdown_nowait`` captures from a foreign thread stays THE set for
    the scheduler's whole life — full decode cycles (populate + two
    finally-clears) and close() mutate it in place, never rebind it."""
    jax = pytest.importorskip("jax")
    from repro.configs import DecodeConfig, get_config
    from repro.models.model import init_model
    from repro.serving import AsyncScheduler, ServingEngine

    cfg = get_config("llada-8b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    dcfg = DecodeConfig(gen_length=16, block_size=8, steps=16,
                        strategy="probability")

    async def main():
        sched = AsyncScheduler(ServingEngine(params, cfg, dcfg,
                                             max_batch=4))
        snapshot = sched._inflight          # a foreign thread's view
        await sched.start()
        rid = sched.submit(np.asarray([3, 5, 2, 7], np.int32))
        events = [e async for e in sched.events(rid)]
        assert events[-1]["type"] == "done"
        await sched.close()
        assert sched._inflight is snapshot
        assert not sched._inflight          # cleared, not replaced

    asyncio.run(main())
