"""The async serving front end, end to end over real sockets: HTTP/SSE
smoke (tier-1 server smoke test), concurrent mixed-length mixed-strategy
traffic bit-identical to direct Decoder output, admission control,
scheduler event semantics, and the memory-budgeted router's observable
cache eviction."""
import asyncio
import dataclasses
import threading

import jax
import numpy as np
import pytest

from repro.configs import (DecodeConfig, RouterConfig, ServerConfig,
                           get_config)
from repro.core import Decoder, decode_cache_info, decode_cache_scope
from repro.models.model import init_model
from repro.serving import (AsyncScheduler, ModelRouter, QueueFullError,
                           ServerError, ServerThread, ServingClient,
                           ServingEngine, params_bytes)

CFG = get_config("llada-8b").reduced()
DCFG = DecodeConfig(gen_length=16, block_size=8, steps=16,
                    strategy="probability")


@pytest.fixture(scope="module")
def params():
    return init_model(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def server(params):
    """One ServerThread for the whole module: model 'tiny' records its
    engine-side block-commit order for the SSE-order assertions."""
    recorded = []

    def factory():
        return ServingEngine(
            params, CFG, DCFG, max_batch=4,
            on_block_committed=lambda reqs, blk, lo, hi, x:
                recorded.append((blk, lo, hi,
                                 sorted(r.rid for r in reqs))))

    router = ModelRouter(RouterConfig())
    router.register("tiny", factory)
    handle = ServerThread(router, ServerConfig(port=0)).start()
    handle.recorded = recorded
    yield handle
    handle.stop()


@pytest.fixture()
def client(server):
    return ServingClient(server.host, server.port)


def _direct(params, prompt, **over):
    """Reference decode, bypassing the whole serving stack.  The rng does
    not matter for the deterministic strategies used here (parity across
    drivers and batch compositions is established in test_loop)."""
    dcfg = dataclasses.replace(DCFG, **over) if over else DCFG
    out, _ = Decoder(params, CFG, dcfg).generate(
        jax.random.PRNGKey(99), np.asarray(prompt, np.int32)[None])
    return np.asarray(out)[0]


# --------------------------------------------------------------------------
# tier-1 server smoke test: one request end-to-end over SSE
# --------------------------------------------------------------------------

def test_server_smoke_sse_end_to_end(server, client, params):
    """Launch on an ephemeral port (module fixture), stream one request,
    and assert the SSE block order matches the engine's
    on_block_committed order exactly."""
    n_before = len(server.recorded)
    prompt = [3, 5, 2, 7, 4, 6]
    events = list(client.generate_stream(prompt))
    names = [name for name, _ in events]
    assert names == ["block", "block", "done"]
    rid = events[-1][1]["rid"]
    committed = [e for e in server.recorded[n_before:] if rid in e[3]]
    # engine-side hook fired once per block, in the same order the SSE
    # stream delivered (lo/hi in canvas coordinates; this request got no
    # pads, so they match the rebased SSE offsets directly)
    assert [(blk, lo, hi) for blk, lo, hi, _ in committed] == \
        [(e["block"], e["lo"], e["hi"]) for name, e in events
         if name == "block"]
    # streamed blocks tile the generated region, in commit order
    done = events[-1][1]
    streamed = sum((e["tokens"] for name, e in events if name == "block"),
                   [])
    assert streamed == done["tokens"][len(prompt):]
    assert done["status"] == "ok"
    assert done["stats"]["steps"] > 0
    # the final text is bit-identical to a direct Decoder decode
    assert done["tokens"] == _direct(params, prompt).tolist()


# --------------------------------------------------------------------------
# the end-to-end acceptance test: N concurrent mixed requests
# --------------------------------------------------------------------------

def test_concurrent_mixed_requests_bit_identical(server, client, params):
    """Six concurrent requests — two prompt lengths (different buckets),
    two strategies (never co-batched) — through client → server →
    scheduler → engine; every final token sequence must be bit-identical
    to decoding that prompt directly through the Decoder."""
    cases = [([3, 5, 2, 7, 4, 6], None),
             ([3, 5, 2, 7, 4, 6], "entropy"),
             ([9, 1, 4, 4, 8, 2, 6, 5, 7, 3, 1, 2, 9, 8], None),
             ([9, 1, 4, 4, 8, 2, 6, 5, 7, 3, 1, 2, 9, 8], "entropy"),
             ([5, 5, 5, 5, 5, 5], "margin"),
             ([2, 4, 6, 8, 1, 3], None)]
    results = [None] * len(cases)
    errors = []

    def worker(i, prompt, strategy):
        try:
            results[i] = client.generate(prompt, strategy=strategy,
                                         wait=True)
        except Exception as e:          # surface in the main thread
            errors.append((i, e))

    threads = [threading.Thread(target=worker, args=(i, p, s))
               for i, (p, s) in enumerate(cases)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errors, errors
    for (prompt, strategy), res in zip(cases, results):
        assert res["status"] == "ok"
        over = {"strategy": strategy} if strategy else {}
        expect = _direct(params, prompt, **over)
        assert res["tokens"] == expect.tolist(), (prompt, strategy)


# --------------------------------------------------------------------------
# request validation + admission control over HTTP
# --------------------------------------------------------------------------

def test_unknown_strategy_is_400(client):
    with pytest.raises(ServerError) as err:
        client.generate([3, 5, 2], strategy="nope")
    assert err.value.status == 400
    assert "unknown strategy" in err.value.message


def test_bad_geometry_is_400(client):
    with pytest.raises(ServerError) as err:
        client.generate([3, 5, 2], gen_length=12, block_size=8)
    assert err.value.status == 400
    with pytest.raises(ServerError) as err:
        client.generate([3, 5, 2], block_size=0)
    assert err.value.status == 400              # not a 500 via div-by-zero


def test_unknown_cache_policy_is_400(client):
    """An unknown policy dies at the submission boundary (ExecutionConfig
    validation inside engine.submit), not deep inside a decode."""
    with pytest.raises(ServerError) as err:
        client.generate([3, 5, 2], cache_policy="lru")
    assert err.value.status == 400
    assert "cache_policy" in err.value.message
    with pytest.raises(ServerError) as err:     # wrong type, typed check
        client.generate([3, 5, 2], cache_policy=7)
    assert err.value.status == 400
    assert "wrong type" in err.value.message


def test_cache_policy_request_over_http(client, params):
    """A prefix-cached request through the full HTTP stack completes and
    matches the direct prefix-cached Decoder output bit-for-bit."""
    prompt = [3, 5, 2, 7, 4, 6]
    res = client.generate(prompt, cache_policy="prefix")
    assert res["status"] == "ok"
    assert res["tokens"] == _direct(params, prompt,
                                    cache_policy="prefix").tolist()


def test_unknown_model_is_404_ish(client):
    with pytest.raises(ServerError) as err:
        client.generate([3, 5, 2], model="missing")
    assert err.value.status == 400              # KeyError at the boundary
    assert "unknown model" in err.value.message


def test_unknown_routes_are_404(client):
    with pytest.raises(ServerError) as err:
        client._request("GET", "/v2/nothing")
    assert err.value.status == 404
    with pytest.raises(ServerError) as err:
        client._request("GET", "/v1/stream/123456")
    assert err.value.status == 404


def test_gen_length_cap_is_400(client):
    with pytest.raises(ServerError) as err:
        client.generate([3, 5, 2], gen_length=1 << 20)
    assert err.value.status == 400
    assert "server cap" in err.value.message


def test_steps_cap_is_400(client):
    """An absurd steps override must be rejected at the boundary — one
    request must not be able to park the decode worker for hours."""
    with pytest.raises(ServerError) as err:
        client.generate([3, 5, 2], steps=100_000_000)
    assert err.value.status == 400
    assert "server cap" in err.value.message


def test_wrong_field_type_is_400_not_a_dropped_connection(client):
    with pytest.raises(ServerError) as err:
        client.generate([3, 5, 2], steps="ten")
    assert err.value.status == 400
    assert "wrong type" in err.value.message


def test_multi_model_rid_routes_need_explicit_model(params):
    """rids are per-model counters: with several models registered,
    /v1/cancel and /v1/stream must refuse to default the model rather
    than touch some other user's same-numbered request."""
    router = ModelRouter(RouterConfig())
    router.register("a",
                    lambda: ServingEngine(params, CFG, DCFG, max_batch=2))
    router.register("b",
                    lambda: ServingEngine(params, CFG, DCFG, max_batch=2))
    handle = ServerThread(router, ServerConfig(port=0)).start()
    try:
        client = ServingClient(handle.host, handle.port)
        with pytest.raises(ServerError) as err:
            client.cancel(0)
        assert err.value.status == 400
        assert "per-model" in err.value.message
        with pytest.raises(ServerError) as err:
            list(client.stream(0))
        assert err.value.status == 400
        # explicit model works end to end
        res = client.generate([3, 5, 2, 7, 4, 6], model="b", wait=True)
        assert res["status"] == "ok" and res["model"] == "b"
    finally:
        handle.stop()


def test_backpressure_429(params):
    """A server with max_queue_depth=0 rejects every submission with 429
    (the deterministic admission-control probe)."""
    router = ModelRouter(RouterConfig())
    router.register("tiny",
                    lambda: ServingEngine(params, CFG, DCFG, max_batch=4))
    handle = ServerThread(router, ServerConfig(
        port=0, max_queue_depth=0)).start()
    try:
        # max_retries=0: this test PROBES the 429, so the client must
        # not helpfully retry it away
        client = ServingClient(handle.host, handle.port, max_retries=0)
        with pytest.raises(ServerError) as err:
            client.generate([3, 5, 2])
        assert err.value.status == 429
        # backpressure is a schedule, not just a refusal
        assert err.value.retry_after is not None
        assert err.value.retry_after >= 1
    finally:
        handle.stop()


def test_healthz_and_metrics(client):
    health = client.healthz()
    assert health["ok"] is True
    assert "tiny" in health["models"]
    text = client.metrics_text()
    assert "repro_up 1" in text
    assert 'repro_queue_depth{model="tiny"}' in text
    assert "repro_decode_cache_entries" in text
    models = client.models()
    assert "probability" in models["strategies"]
    assert models["models"]["tiny"]["resident"] is True


# --------------------------------------------------------------------------
# scheduler event semantics (no sockets: pure asyncio)
# --------------------------------------------------------------------------

def test_scheduler_backpressure_and_cancel_events(params):
    async def main():
        engine = ServingEngine(params, CFG, DCFG, max_batch=4)
        sched = AsyncScheduler(engine, max_queue_depth=1)
        # worker not started: the queue cannot drain under us
        rid = sched.submit(np.full((6,), 3, np.int32))
        with pytest.raises(QueueFullError):
            sched.submit(np.full((6,), 3, np.int32))
        assert sched.counters["rejected"] == 1
        assert sched.cancel(rid) is True
        events = [e async for e in sched.events(rid)]
        assert [e["type"] for e in events] == ["cancelled"]
        assert events[-1]["final"] is True
        # replay: a second reader sees the identical stream
        again = [e async for e in sched.events(rid)]
        assert again == events

    asyncio.run(main())


def test_scheduler_batch_error_does_not_kill_the_loop(params):
    """A once-flaky batch is retried by supervision and COMPLETES; a
    persistently failing singleton gets a terminal error event; requests
    behind both are still served (the worker loop survives).  The full
    supervision matrix lives in test_faults.py."""
    async def main():
        engine = ServingEngine(params, CFG, DCFG, max_batch=4)
        real = engine.decode_batch_blocks
        calls = {"n": 0}

        def flaky(batch):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("boom")
            return real(batch)

        engine.decode_batch_blocks = flaky
        sched = AsyncScheduler(engine)
        await sched.start()
        flaky_rid = sched.submit(np.full((6,), 3, np.int32))
        terminal = await sched.result(flaky_rid)
        assert terminal["type"] == "done"       # transient → retried
        assert sched.counters["retries"] == 1

        def always(batch):
            calls["n"] += 1
            raise RuntimeError("boom")

        engine.decode_batch_blocks = always
        bad = sched.submit(np.full((6,), 3, np.int32))
        terminal = await sched.result(bad)
        assert terminal["type"] == "error"      # retries exhausted
        assert "boom" in terminal["error"]
        engine.decode_batch_blocks = real
        good = sched.submit(np.full((6,), 3, np.int32))
        terminal = await sched.result(good)
        assert terminal["type"] == "done"
        assert sched.counters["errors"] == 1
        assert sched.counters["quarantined"] == 1
        await sched.close()

    asyncio.run(main())


def test_scheduler_deadline_emits_expired_event(params):
    async def main():
        engine = ServingEngine(params, CFG, DCFG, max_batch=4)
        sched = AsyncScheduler(engine)
        await sched.start()
        # a deadline already in the past: expiry is deterministic, not a
        # race against the worker's first wakeup
        rid = sched.submit(np.full((6,), 3, np.int32), deadline_s=-1.0)
        await asyncio.sleep(0.05)       # let the worker reap it
        terminal = await sched.result(rid)
        assert terminal["type"] == "expired"
        assert sched.counters["expired"] == 1
        # explicit deadline_s=0 follows the server convention: NO
        # deadline (not expire-immediately) — the request decodes
        rid = sched.submit(np.full((6,), 3, np.int32), deadline_s=0)
        terminal = await sched.result(rid)
        assert terminal["type"] == "done"
        await sched.close()

    asyncio.run(main())


# --------------------------------------------------------------------------
# memory-budgeted multi-model router: observable cache eviction + hot swap
# --------------------------------------------------------------------------

def _make_factory(seed):
    def factory():
        fresh = init_model(jax.random.PRNGKey(seed), CFG)
        return ServingEngine(fresh, CFG, DCFG, max_batch=2)
    return factory


def _decode_once(engine):
    rid = engine.submit(np.full((6,), 3, np.int32))
    engine.run_until_idle()
    return engine.result(rid).result


def test_router_budget_evicts_idle_lru_and_frees_cache():
    """Two models under a budget that fits only one: touching B must
    force-drop idle A, and the drop must be visible in the weak runner
    cache (entries shrink — nothing pins the evicted weights)."""
    with decode_cache_scope():
        probe = _make_factory(1)()
        one_model_bytes = params_bytes(probe.params)
        del probe
        router = ModelRouter(RouterConfig(
            budget_bytes=int(one_model_bytes * 1.5)))
        router.register("a", _make_factory(1))
        router.register("b", _make_factory(2))
        _decode_once(router.engine("a"))
        assert decode_cache_info().entries == 1
        assert router.resident("a")
        _decode_once(router.engine("b"))    # over budget → A evicted
        assert not router.resident("a")
        assert router.resident("b")
        assert router.counters["evictions"] == 1
        assert router.resident_bytes() <= int(one_model_bytes * 1.5)
        # the evicted engine's params were the cache key anchors: its
        # entry (and compiled runners) went with it
        assert decode_cache_info().entries == 1
        # A rebuilds on demand from its factory
        _decode_once(router.engine("a"))
        assert router.resident("a") and not router.resident("b")


def test_router_never_evicts_busy_engines():
    with decode_cache_scope():
        nbytes = params_bytes(_make_factory(1)().params)
        router = ModelRouter(RouterConfig(budget_bytes=nbytes))
        router.register("a", _make_factory(1))
        router.register("b", _make_factory(2))
        engine_a = router.engine("a")
        engine_a.submit(np.full((6,), 3, np.int32))     # queued → busy
        router.engine("b")
        # both resident: the budget transiently overshoots rather than
        # dropping a busy engine
        assert router.resident("a") and router.resident("b")
        engine_a.run_until_idle()
        router.engine("b")                  # next touch enforces again
        assert not router.resident("a")


def test_router_hot_swap_evicts_old_weights():
    """Hot swap = build a new engine; the old engine's runner-cache entry
    must evict with its params (weak cache), and the new engine decodes."""
    with decode_cache_scope():
        router = ModelRouter(RouterConfig())
        router.register("a", _make_factory(1))
        out_old = _decode_once(router.engine("a"))
        assert decode_cache_info().entries == 1
        swapped = router.hot_swap("a", _make_factory(3))
        out_new = _decode_once(swapped)
        info = decode_cache_info()
        assert info.entries == 1            # old entry gone, new one live
        assert router.counters["swaps"] == 1
        assert out_old.shape == out_new.shape
        assert not np.array_equal(out_old, out_new)   # weights changed


def test_router_unknown_model_raises():
    router = ModelRouter(RouterConfig())
    with pytest.raises(KeyError, match="unknown model"):
        router.engine("ghost")
