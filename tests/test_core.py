"""Unit tests for the paper's core: confidence, FDM, FDM-A, strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DecodeConfig, get_config
from repro.core import (apply_mask, commit_topn, fully_masked,
                        global_confidence, mask_positions,
                        masked_cross_entropy, rank_desc, score_logits)
from repro.core.fdm import fdm_select
from repro.core.fdm_a import fdm_a_plan

CFG = get_config("llada-8b").reduced()


def test_score_logits_consistency(rng):
    logits = 3 * jax.random.normal(rng, (2, 5, 101))
    s = score_logits(logits)
    p = jax.nn.softmax(logits, axis=-1)
    np.testing.assert_array_equal(s.argmax, jnp.argmax(logits, -1))
    np.testing.assert_allclose(s.max_prob, jnp.max(p, -1), rtol=1e-5)
    assert (s.margin >= -1e-6).all() and (s.margin <= s.max_prob + 1e-6).all()
    assert (s.neg_entropy <= 1e-6).all()


def test_global_confidence_prefers_confident_states(rng):
    """A peaked next-state distribution has higher C_global (Eq. 10)."""
    peaked = jnp.zeros((1, 4, 50)).at[..., 0].set(20.0)
    flat = jnp.zeros((1, 4, 50))
    masked = jnp.ones((1, 4), bool)
    assert float(global_confidence(peaked, masked)[0]) > \
        float(global_confidence(flat, masked)[0])


def test_global_confidence_counts_only_masked():
    logits = jnp.zeros((1, 4, 50))
    half = jnp.array([[True, True, False, False]])
    full = jnp.ones((1, 4), bool)
    assert float(global_confidence(logits, half)[0]) == \
        pytest.approx(float(global_confidence(logits, full)[0]) / 2)


def test_rank_and_commit_topn():
    conf = jnp.array([[0.1, 0.9, 0.5, 0.7]])
    assert rank_desc(conf)[0, 1] == 0 and rank_desc(conf)[0, 0] == 3
    x = jnp.full((1, 4), 9, jnp.int32)
    cand = jnp.arange(4)[None]
    out = commit_topn(x, conf, cand, jnp.ones((1, 4), bool), 2)
    np.testing.assert_array_equal(out, [[9, 1, 9, 3]])


def test_commit_topn_respects_eligibility():
    conf = jnp.array([[0.9, 0.8, 0.7, 0.6]])
    eligible = jnp.array([[False, True, False, True]])
    x = jnp.full((1, 4), 9, jnp.int32)
    out = commit_topn(x, conf, jnp.arange(4)[None], eligible, 2)
    np.testing.assert_array_equal(out, [[9, 1, 9, 3]])


def test_apply_mask_only_masks_maskable(rng):
    tokens = jnp.arange(32).reshape(2, 16) % CFG.vocab_size
    maskable = jnp.zeros((2, 16), bool).at[:, 8:].set(True)
    t = jnp.array([1.0, 1.0])   # mask everything maskable
    corrupted, masked = apply_mask(rng, tokens, t, CFG, maskable)
    assert not masked[:, :8].any()
    assert masked[:, 8:].all()
    assert (corrupted[:, 8:] == CFG.mask_token_id).all()


def test_masked_cross_entropy_perfect_prediction():
    v = 32
    targets = jnp.array([[3, 5, 7]])
    logits = jax.nn.one_hot(targets, v) * 100.0
    masked = jnp.ones((1, 3), bool)
    loss, _ = masked_cross_entropy(logits, targets, masked, jnp.ones((1,)))
    assert float(loss) < 1e-3


class _ToyModel:
    """Deterministic model for FDM semantics tests: position i prefers
    token i, confidence rises with the number of committed tokens."""

    def __init__(self, vocab, peak=4.0):
        self.vocab = vocab
        self.peak = peak

    def __call__(self, x):
        b, l = x.shape
        committed = jnp.sum(x != CFG.mask_token_id, axis=-1, keepdims=True)
        conf = 1.0 + self.peak * committed / l
        pos_tok = jnp.arange(l) % (self.vocab - 1)
        logits = jax.nn.one_hot(pos_tok, self.vocab) * conf[..., None]
        return jnp.broadcast_to(logits, (b, l, self.vocab))


def test_fdm_select_commits_exactly_n():
    model = _ToyModel(CFG.vocab_size)
    x = jnp.full((2, 8), CFG.mask_token_id, jnp.int32)
    active = jnp.ones((2, 8), bool)
    logits = model(x)
    for n in [1, 2, 3]:
        new_x, _ = fdm_select(x, logits, active, model, CFG,
                              k=2, gamma=0.0, n=n)
        committed = (new_x != CFG.mask_token_id).sum(axis=-1)
        np.testing.assert_array_equal(committed, [n, n])


def test_fdm_select_falls_back_when_pruned():
    """γ above every confidence -> Λ = ∅ -> local-only commit still occurs."""
    model = _ToyModel(CFG.vocab_size, peak=0.0)
    x = jnp.full((1, 6), CFG.mask_token_id, jnp.int32)
    logits = model(x)
    new_x, _ = fdm_select(x, logits, jnp.ones((1, 6), bool), model, CFG,
                          k=2, gamma=0.999, n=1)
    assert int((new_x != CFG.mask_token_id).sum()) == 1


def test_fdm_a_plan_phases():
    dcfg = DecodeConfig(eta1=0.8, eta2=0.6, n_max=4)
    v = 16

    def logits_with_probs(probs):
        """Build logits whose per-position max prob ≈ probs."""
        out = []
        for p in probs:
            rest = (1 - p) / (v - 1)
            row = jnp.log(jnp.full((v,), rest).at[0].set(p))
            out.append(row)
        return jnp.stack(out)[None]

    active = jnp.ones((1, 4), bool)
    # exploration: nothing above eta1
    s, n, gamma, need, phases = fdm_a_plan(
        logits_with_probs([0.5, 0.5, 0.5, 0.5]), active, dcfg)
    assert bool(need[0]) and int(n[0]) == 1
    assert float(gamma[0]) == pytest.approx(dcfg.gamma1)
    # acceleration: >= N qualified
    s, n, gamma, need, phases = fdm_a_plan(
        logits_with_probs([0.95, 0.95, 0.95, 0.95]), active, dcfg)
    assert not bool(need[0]) and int(n[0]) == 4
    # balance: qualified + borderline
    s, n, gamma, need, phases = fdm_a_plan(
        logits_with_probs([0.95, 0.7, 0.3, 0.3]), active, dcfg)
    assert bool(need[0]) and int(n[0]) == 1
    assert float(gamma[0]) == pytest.approx(dcfg.eta2)
    # local-only: qualified, no borderline
    s, n, gamma, need, phases = fdm_a_plan(
        logits_with_probs([0.95, 0.3, 0.3, 0.3]), active, dcfg)
    assert not bool(need[0]) and int(n[0]) == 1


def test_fully_masked_layout():
    prompt = jnp.ones((2, 5), jnp.int32)
    x = fully_masked(CFG, prompt, 8)
    assert x.shape == (2, 13)
    assert (x[:, 5:] == CFG.mask_token_id).all()
    assert mask_positions(x, CFG)[:, 5:].all()
