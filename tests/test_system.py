"""End-to-end behaviour tests: training reduces loss, every strategy
decodes to completion, FDM-A commits more tokens per forward, the serving
engine round-trips requests, checkpoints restore exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DecodeConfig, TrainConfig, get_config
from repro.core import Decoder
from repro.data import CharTokenizer, TaskDataset
from repro.models.model import forward
from repro.serving import ServingEngine
from repro.training import adamw_init, load, save, train

CFG = get_config("llada-8b").reduced()


@pytest.fixture(scope="module")
def trained():
    """One small model trained on `sum` shared across system tests."""
    tok = CharTokenizer(CFG.vocab_size)
    ds = TaskDataset("sum", tok)
    tcfg = TrainConfig(batch_size=32, seq_len=ds.seq_len, steps=150,
                       log_every=1000)
    params, history = train(CFG, tcfg, ds.batches(tcfg.batch_size),
                            log=None)
    return params, ds, tok, history


def test_training_reduces_loss(trained):
    _, _, _, history = trained
    assert history["loss"][-1] < history["loss"][0] * 0.7


@pytest.mark.parametrize("strategy", ["random", "probability", "margin",
                                      "entropy", "eb", "wino", "fdm",
                                      "fdm_a"])
def test_every_strategy_completes(trained, strategy):
    params, ds, tok, _ = trained
    model_fn = jax.jit(lambda x: forward(params, x, CFG)[0])
    batch = ds.eval_batch(4)
    prompts = jnp.asarray(ds.prompts_only(batch))
    gen = ds.seq_len - prompts.shape[1]
    dcfg = DecodeConfig(gen_length=gen, block_size=gen, steps=gen,
                        strategy=strategy, k=2, k1=2)
    out, stats = Decoder(model_fn, CFG, dcfg).generate(
        jax.random.PRNGKey(0), prompts)
    assert out.shape == (4, ds.seq_len)
    assert not (out == CFG.mask_token_id).any(), strategy
    assert stats.steps >= 1


def test_fdm_a_uses_fewer_steps_than_fdm(trained):
    params, ds, tok, _ = trained
    model_fn = jax.jit(lambda x: forward(params, x, CFG)[0])
    prompts = jnp.asarray(ds.prompts_only(ds.eval_batch(4)))
    gen = ds.seq_len - prompts.shape[1]
    base = dict(gen_length=gen, block_size=gen, steps=gen, k=2, k1=2)
    _, s_fdm = Decoder(model_fn, CFG, DecodeConfig(strategy="fdm", **base)
                       ).generate(jax.random.PRNGKey(0), prompts)
    _, s_a = Decoder(model_fn, CFG, DecodeConfig(strategy="fdm_a", **base)
                     ).generate(jax.random.PRNGKey(0), prompts)
    assert s_a.steps <= s_fdm.steps
    assert s_a.tokens_per_forward >= s_fdm.tokens_per_forward


def test_cached_generation_matches_full(trained):
    """KV-cached decoding must track the full re-forward sampler closely
    and leave no masks.  ``prefix`` keeps the whole generation region
    live (only prompt deep-layer K/V are frozen between refreshes) so it
    tracks tightly; ``dual`` additionally serves the masked suffix from
    the cache — the Fast-dLLM approximation — so its floor is looser.
    Thresholds reflect a deliberately lightly-trained fixture (a
    well-trained testbed model measures ≥0.99 for prefix — see
    benchmarks/kv_cache)."""
    import dataclasses
    params, ds, tok, _ = trained
    model_fn = jax.jit(lambda x: forward(params, x, CFG)[0])
    prompts = jnp.asarray(ds.prompts_only(ds.eval_batch(8)))
    gen = ds.seq_len - prompts.shape[1]
    bs = gen // 2 if gen % 2 == 0 else gen
    for strategy in ["probability", "fdm_a"]:
        dcfg = DecodeConfig(gen_length=gen, block_size=bs, steps=gen,
                            strategy=strategy)
        o1, _ = Decoder(model_fn, CFG, dcfg).generate(
            jax.random.PRNGKey(0), prompts)
        for policy, floor in (("prefix", 0.85), ("dual", 0.6)):
            o2, _ = Decoder(params, CFG,
                            dataclasses.replace(dcfg, cache_policy=policy)
                            ).generate(jax.random.PRNGKey(0), prompts)
            assert not (o2 == CFG.mask_token_id).any()
            agree = float(jnp.mean((o1 == o2).astype(jnp.float32)))
            assert agree >= floor, (strategy, policy, agree)


def test_serving_engine_roundtrip(trained):
    params, ds, tok, _ = trained
    gen = ds.seq_len - (1 + ds.prompt_len)
    dcfg = DecodeConfig(gen_length=gen, block_size=gen, steps=gen,
                        strategy="probability")
    engine = ServingEngine(params, CFG, dcfg, max_batch=4)
    batch = ds.eval_batch(6)
    prompts = ds.prompts_only(batch)
    rids = [engine.submit(prompts[i]) for i in range(6)]
    engine.run_until_idle()
    for rid in rids:
        req = engine.result(rid)
        assert req.result is not None
        assert req.result.shape == (ds.seq_len,)
        assert req.latency >= 0
    summary = engine.summary()
    assert summary["requests"] == 6
    assert summary["throughput_tps"] > 0


def test_checkpoint_roundtrip(tmp_path, trained):
    params, _, _, _ = trained
    opt = adamw_init(params)
    path = str(tmp_path / "ckpt.npz")
    save(path, params, opt, step=42)
    p2, o2, step = load(path, params, opt)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tokenizer_roundtrip():
    tok = CharTokenizer(128)
    s = "12+34=046"
    assert tok.decode(tok.encode(s)) == s


def test_dataset_geometry_static():
    tok = CharTokenizer(128)
    for task in ["sum", "sort", "parity", "bracket", "reverse"]:
        ds = TaskDataset(task, tok)
        b = next(ds.batches(8))
        assert b["tokens"].shape == (8, ds.seq_len)
        assert b["maskable"].shape == (8, ds.seq_len)
        # prompts never maskable, geometry identical across samples
        assert not b["maskable"][:, :1 + ds.prompt_len].any()
        b2 = next(ds.batches(8))
        assert b2["tokens"].shape == b["tokens"].shape
