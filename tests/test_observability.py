"""End-to-end decode observability: the metrics registry (Prometheus
text exposition), on-device step telemetry (``dcfg.trace`` →
``SampleStats.trace``), request tracing through the serving stack
(``/v1/trace/{rid}`` Chrome trace-event JSON), and the ANA105 telemetry
contract."""
import dataclasses
import importlib.util
import io
import json
import os
import threading

import jax
import numpy as np
import pytest

from repro.configs import (DecodeConfig, RouterConfig, ServerConfig,
                           get_config)
from repro.core import Decoder, decode_cache_scope, decode_cache_info
from repro.core.decoder import SampleStats
from repro.core.tracebuffer import DecodeTrace, trace_capacity, tracing
from repro.models.model import init_model
from repro.serving import (ModelRouter, ServerError, ServerThread,
                           ServingClient, ServingEngine)
from repro.serving.metrics import (CONTENT_TYPE, Family, MetricsRegistry,
                                   escape_label_value, format_value)
from repro.serving.tracing import Span, TraceStore, chrome_trace

CFG = get_config("llada-8b").reduced()
DCFG = DecodeConfig(gen_length=16, block_size=8, steps=16,
                    strategy="probability")
PROMPT = [3, 5, 2, 7, 4, 6]


@pytest.fixture(scope="module")
def params():
    return init_model(jax.random.PRNGKey(0), CFG)


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_registry_renders_help_type_and_bare_ints():
    reg = MetricsRegistry()
    reg.gauge("g", "a gauge").set(3)
    reg.counter("c_total", "a counter", ("model",)) \
        .labels(model="tiny").inc(2)
    text = reg.render()
    assert "# HELP g a gauge\n# TYPE g gauge\ng 3\n" in text
    assert '# TYPE c_total counter\nc_total{model="tiny"} 2\n' in text
    assert text.endswith("\n")


def test_registry_label_escaping_round_trip():
    reg = MetricsRegistry()
    nasty = 'ti"ny\\mod\nel'
    reg.gauge("g", "h", ("model",)).labels(model=nasty).set(1)
    line = [l for l in reg.render().splitlines()
            if not l.startswith("#")][0]
    assert line == 'g{model="ti\\"ny\\\\mod\\nel"} 1'
    assert escape_label_value(nasty) in line


def test_histogram_buckets_cumulative_with_inf_sum_count():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "h", ("model",),
                      buckets=(0.1, 1.0))
    child = h.labels(model="a")
    for v in (0.05, 0.5, 2.0):
        child.observe(v)
    lines = reg.render().splitlines()
    assert 'lat_seconds_bucket{model="a",le="0.1"} 1' in lines
    assert 'lat_seconds_bucket{model="a",le="1"} 2' in lines
    assert 'lat_seconds_bucket{model="a",le="+Inf"} 3' in lines
    assert 'lat_seconds_sum{model="a"} 2.55' in lines
    assert 'lat_seconds_count{model="a"} 3' in lines


def test_registry_instrument_misuse_raises():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "h")
    with pytest.raises(ValueError):
        c.inc(-1)
    assert reg.counter("c_total", "h") is c       # idempotent re-get
    with pytest.raises(ValueError):
        reg.gauge("c_total", "h")                 # type conflict
    with pytest.raises(ValueError):
        reg.counter("c_total", "h", ("model",))   # label conflict
    with pytest.raises(ValueError):
        c.labels(model="x")                       # undeclared label


def test_collector_families_render_live_snapshots():
    reg = MetricsRegistry()
    state = {"v": 1}
    reg.register_collector(lambda: [
        Family("live", "gauge", "snapshot", [({}, state["v"])])])
    assert "live 1" in reg.render()
    state["v"] = 7
    assert "live 7" in reg.render()


def test_format_value_spellings():
    assert format_value(True) == "1"
    assert format_value(3) == "3"
    assert format_value(3.0) == "3"
    assert format_value(float("inf")) == "+Inf"
    assert format_value(float("nan")) == "NaN"
    assert format_value(2.55) == "2.55"


# --------------------------------------------------------------------------
# SampleStats.as_dict — the one stable stats shape
# --------------------------------------------------------------------------

def test_as_dict_is_unrounded_and_json_safe():
    stats = SampleStats(steps=16, forward_equivalents=16 / 3,
                        wall_time=0.123456789, tokens_generated=16,
                        revocations=1.0, skipped_forwards=2.0,
                        phase_counts={"explore": 4.0})
    d = stats.as_dict()
    assert d["forward_equivalents"] == pytest.approx(16 / 3, rel=1e-12)
    assert d["wall_time_s"] == pytest.approx(0.123456789, rel=1e-12)
    assert d["tps"] == pytest.approx(stats.tps, rel=1e-12)
    assert d["tokens_per_forward"] == pytest.approx(
        stats.tokens_per_forward, rel=1e-12)
    json.dumps(d)                                 # trace stays off-wire
    assert "trace" not in d


# --------------------------------------------------------------------------
# on-device step telemetry: parity, isolation, histogram invariant
# --------------------------------------------------------------------------

def _decode(params, *, trace, fused_loop=True, fused_blocks=True,
            strategy="probability"):
    dcfg = dataclasses.replace(DCFG, trace=trace, fused_loop=fused_loop,
                               fused_blocks=fused_blocks,
                               strategy=strategy)
    dec = Decoder(params, CFG, dcfg)
    out, stats = dec.generate(jax.random.PRNGKey(7),
                              np.asarray(PROMPT, np.int32)[None])
    return np.asarray(out), stats


def test_trace_off_is_bit_identical_and_recompile_free(params):
    with decode_cache_scope():
        off, s_off = _decode(params, trace=False)
        base = decode_cache_info()
        on, s_on = _decode(params, trace=True)
        off2, _ = _decode(params, trace=False)
        after = decode_cache_info()
    np.testing.assert_array_equal(off, on)        # telemetry is passive
    np.testing.assert_array_equal(off, off2)
    assert s_off.trace is None and s_on.trace is not None
    # the traced decode uses its own runner; the untraced repeat re-hits
    # the original — trace=on never invalidates the trace=off cache
    assert after.hits > base.hits


@pytest.mark.parametrize("fused_loop,fused_blocks",
                         [(True, True), (True, False), (False, False)])
def test_trace_parity_across_drivers(params, fused_loop, fused_blocks):
    ref = _decode(params, trace=True)[1].trace
    trace = _decode(params, trace=True, fused_loop=fused_loop,
                    fused_blocks=fused_blocks)[1].trace
    np.testing.assert_array_equal(ref.commit_step, trace.commit_step)
    np.testing.assert_array_equal(ref.commits, trace.commits)
    np.testing.assert_array_equal(ref.block, trace.block)
    np.testing.assert_array_equal(ref.skipped, trace.skipped)


@pytest.mark.parametrize("strategy", ["probability", "wino_r"])
def test_commit_histogram_sums_to_tokens_generated(params, strategy):
    """Under revocation (wino_r) raw per-step commits overcount; the
    FINAL-commit histogram still sums exactly to tokens_generated."""
    out, stats = _decode(params, trace=True, strategy=strategy)
    trace = stats.trace
    hist = trace.commit_histogram()
    assert hist.sum() == stats.tokens_generated
    assert hist.shape == (trace.steps,)
    assert trace.steps <= trace_capacity(DCFG)
    # committed positions are exactly the generated region
    assert (trace.commit_step >= 0).sum() == stats.tokens_generated


def test_tracing_wrapper_memoized_and_idempotent():
    from repro.core.strategies import as_strategy
    from repro.core.tracebuffer import TracingStrategy
    inner = as_strategy("probability")
    wrapped = tracing(inner)
    assert tracing(inner) is wrapped      # identity-stable: runner cache
    assert tracing(wrapped) is wrapped    # idempotent, never double-wraps
    with pytest.raises(TypeError):
        TracingStrategy(wrapped)


# --------------------------------------------------------------------------
# TraceStore / chrome_trace
# --------------------------------------------------------------------------

def _fake_decode_trace(steps=4, length=8):
    commit_step = np.arange(length).reshape(1, -1) % steps
    return DecodeTrace(
        commit_step=commit_step.astype(np.int32),
        commit_conf=np.ones((1, length), np.float32),
        commits=np.full((steps,), length // steps, np.int32),
        revocations=np.zeros((steps,), np.int32),
        skipped=np.zeros((steps,), bool),
        phase=np.full((steps,), -1, np.int32),
        block=np.zeros((steps,), np.int32))


def test_trace_store_retention_fifo():
    store = TraceStore(retain=2)
    for rid in range(4):
        store.add(rid, Span("queue_wait", "serving", 0.0, 1.0))
        store.retire(rid)
    assert not store.known(0) and not store.known(1)
    assert store.known(2) and store.known(3)
    with pytest.raises(KeyError):
        store.chrome(0)


def test_chrome_trace_shape_and_counter_sum():
    spans = [Span("queue_wait", "serving", 0.0, 0.1),
             Span("decode_block[0]", "decode", 0.1, 0.5, {"block": 0}),
             Span("emit", "serving", 0.5, 0.6)]
    trace = _fake_decode_trace()
    out = chrome_trace(5, spans, trace, {"rid": 5})
    json.dumps(out)                               # wire-safe
    events = out["traceEvents"]
    assert out["displayTimeUnit"] == "ms"
    names = {e["name"] for e in events}
    assert {"queue_wait", "decode_block[0]", "emit"} <= names
    counters = [e for e in events if e.get("ph") == "C"]
    assert len(counters) == trace.steps
    assert sum(e["args"]["commits"] for e in counters) == \
        int((trace.commit_step >= 0).sum())
    # device events sit inside the decode spans' extent, on their own tid
    device = [e for e in events if e.get("cat") == "device"
              and e.get("ph") == "X"]
    assert all(0.1e6 <= e["ts"] <= 0.5e6 for e in device)
    assert len({e["tid"] for e in device}) == 1


def test_trace_view_renders_terminal_table(tmp_path):
    spec = importlib.util.spec_from_file_location(
        "trace_view", os.path.join(os.path.dirname(__file__), os.pardir,
                                   "tools", "trace_view.py"))
    view = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(view)
    out = chrome_trace(1, [Span("decode_block[0]", "decode", 0.0, 1.0)],
                       _fake_decode_trace(), {"rid": 1})
    path = tmp_path / "trace.json"
    path.write_text(json.dumps(out))
    buf = io.StringIO()
    view.render(view.load(str(path)), out=buf)
    text = buf.getvalue()
    assert "decode_block[0]" in text
    assert "total committed tokens: 8" in text


# --------------------------------------------------------------------------
# the serving stack end to end
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def server(params):
    router = ModelRouter(RouterConfig())
    router.register("tiny", lambda: ServingEngine(params, CFG, DCFG,
                                                  max_batch=4))
    handle = ServerThread(router, ServerConfig(port=0)).start()
    yield handle
    handle.stop()


@pytest.fixture()
def client(server):
    return ServingClient(server.host, server.port)


def test_server_trace_end_to_end(client):
    done = client.generate(PROMPT, trace=True, wait=True)
    rid = done["rid"]
    trace = client.trace(rid)
    events = trace["traceEvents"]
    names = {e["name"] for e in events}
    assert "queue_wait" in names and "batch_assembly" in names
    assert any(n.startswith("decode_block[") for n in names)
    assert "emit" in names
    # the on-device counters are present and sum to tokens_generated
    commits = sum(e["args"]["commits"] for e in events
                  if e.get("ph") == "C" and e["name"] == "commits")
    assert commits == done["stats"]["tokens_generated"] \
        == DCFG.gen_length
    assert trace["otherData"]["strategy"] == "probability"


def test_server_trace_off_spans_only(client):
    done = client.generate(PROMPT, wait=True)
    trace = client.trace(done["rid"])
    assert any(e["name"] == "queue_wait"
               for e in trace["traceEvents"])
    assert not any(e.get("cat") == "device"
                   for e in trace["traceEvents"])


def test_server_trace_errors(client):
    with pytest.raises(ServerError) as e:
        client.trace(10 ** 9)
    assert e.value.status == 404
    with pytest.raises(ServerError) as e:
        client.generate(PROMPT, trace="yes")      # type: ignore[arg-type]
    assert e.value.status == 400


def test_metrics_exposition_conformance(client):
    client.generate(PROMPT, wait=True)            # ensure decode counters
    text = client.metrics_text()
    lines = text.splitlines()
    assert "repro_up 1" in lines
    # every sample line belongs to a family declared with # TYPE first
    declared = set()
    for line in lines:
        if line.startswith("# TYPE "):
            declared.add(line.split()[2])
        elif line and not line.startswith("#"):
            base = line.split("{")[0].split(" ")[0]
            family = base
            for suffix in ("_bucket", "_sum", "_count"):
                if base.endswith(suffix) and base[: -len(suffix)] \
                        in declared:
                    family = base[: -len(suffix)]
            assert family in declared, line
    # seed-era series survive the registry rewrite verbatim
    assert any(l.startswith('repro_queue_depth{model="tiny"}')
               for l in lines)
    assert any(l.startswith("repro_decode_cache_entries")
               for l in lines)
    assert any(l.startswith(
        'repro_requests_finished_total{model="tiny"}') for l in lines)
    # the new registry instruments are live
    assert any(l.startswith('repro_request_latency_seconds_bucket'
                            '{model="tiny",le=') for l in lines)
    assert any(l.startswith('repro_decodes_total{model="tiny",'
                            'strategy="probability"') for l in lines)


def test_concurrent_metrics_scrape_during_decode(client):
    """/metrics stays scrapeable while a decode is in flight: the
    registry lock never waits on the decode thread."""
    sub = client.generate(PROMPT, trace=True, wait=False)
    texts, stop = [], threading.Event()

    def scrape():
        while not stop.is_set():
            texts.append(client.metrics_text())

    t = threading.Thread(target=scrape)
    t.start()
    try:
        events = list(client.stream(sub["rid"]))
    finally:
        stop.set()
        t.join()
    assert events[-1][0] == "done"
    assert texts and all("repro_up 1" in x for x in texts)
    final = client.metrics_text()
    assert 'repro_tokens_per_request_count{model="tiny"}' in final


# --------------------------------------------------------------------------
# ANA105: the telemetry contract
# --------------------------------------------------------------------------

def test_ana105_rule_registered():
    from repro.analysis.findings import RULES
    severity, _ = RULES["ANA105"]
    assert severity == "error"


def test_ana105_clean_for_stock_strategy():
    from repro.analysis.conformance import check_trace_telemetry
    assert check_trace_telemetry("probability") == []
