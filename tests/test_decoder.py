"""The first-class Decoder / Strategy API (core/decoder.py,
core/strategies.py): registry round-trip with a custom carry-ful strategy,
cross-call runner-cache hits and weak eviction, and per-block streaming
callbacks — under every cache policy."""
import dataclasses
import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DecodeConfig, get_config
from repro.core import (Decoder, Strategy, available_strategies,
                        commit_topn, decode_cache_info, decode_cache_scope,
                        register_strategy, reset_decode_cache_stats,
                        resolve_strategy, score_logits, unregister_strategy)
from repro.core.decoder import RunnerCache
from repro.models.model import forward, init_model

CFG = get_config("llada-8b").reduced()


@pytest.fixture(scope="module")
def model():
    params = init_model(jax.random.PRNGKey(0), CFG)
    model_fn = jax.jit(lambda x: forward(params, x, CFG)[0])
    return params, model_fn


def _dcfg(**over):
    base = dict(gen_length=16, block_size=8, steps=16, k=2, k1=2,
                strategy="probability")
    base.update(over)
    return DecodeConfig(**base)


# --------------------------------------------------------------------------
# registry round-trip: a custom strategy decodes end-to-end, no core edits
# --------------------------------------------------------------------------

class AlternatingStrategy(Strategy):
    """Toy carry-ful strategy: alternates between committing 1 and 2
    tokens per step (the carry is a device step counter), exercising both
    init_carry threading and fused/host parity for out-of-tree code."""

    name = "alternating"

    def init_carry(self, cfg, dcfg):
        return jnp.zeros((), jnp.int32)

    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        logits = model_fn(x)
        s = score_logits(logits)
        take = jnp.where(carry % 2 == 0, 1, 2)
        new_x = commit_topn(x, s.max_prob, s.argmax, active,
                            jnp.full((x.shape[0],), take))
        return new_x, carry + 1, 1


@pytest.fixture()
def alternating():
    register_strategy(AlternatingStrategy(), replace=True)
    yield
    unregister_strategy("alternating")


def test_custom_strategy_registry_roundtrip(model, alternating):
    assert "alternating" in available_strategies()
    assert resolve_strategy("alternating").name == "alternating"
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy="alternating")
    dec = Decoder(params, CFG, dcfg)
    out_f, s_f = dec.generate(jax.random.PRNGKey(0), prompts)
    assert out_f.shape == (2, 22)
    assert not (np.asarray(out_f) == CFG.mask_token_id).any()
    # the carry made commit widths alternate 1,2,1,2… -> fewer steps than
    # the 16 a 1-per-step strategy needs, more than the 8 of 2-per-step
    assert 8 < s_f.steps < 16
    # fused/host parity holds for out-of-tree strategies too
    out_h, s_h = Decoder(params, CFG,
                         dataclasses.replace(dcfg, fused_loop=False)
                         ).generate(jax.random.PRNGKey(0), prompts)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_h))
    assert s_f.steps == s_h.steps


def test_custom_strategy_carry_survives_cached_path(model, alternating):
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    dec = Decoder(params, CFG, _dcfg(strategy="alternating",
                                     cache_policy="prefix"))
    out, _ = dec.generate(jax.random.PRNGKey(0), prompts)
    assert not (np.asarray(out) == CFG.mask_token_id).any()


def test_register_strategy_rejects_duplicates(alternating):
    with pytest.raises(ValueError):
        register_strategy(AlternatingStrategy())
    with pytest.raises(KeyError):
        resolve_strategy("definitely-not-registered")


def test_generate_rejects_unknown_extras(model):
    params, _ = model
    with pytest.raises(TypeError, match="unexpected keyword"):
        Decoder(params, CFG, _dcfg()).generate(
            jax.random.PRNGKey(0), jnp.full((1, 4), 2, jnp.int32),
            on_block_comitted=lambda *a: None)      # the typo'd spelling


# --------------------------------------------------------------------------
# cross-call cache: zero recompiles on repeat, weak eviction on GC
# --------------------------------------------------------------------------

def test_cross_call_cache_zero_recompiles(model):
    """A second decode with the same params — even through a *new*
    Decoder — must neither build nor trace anything, in both the plain
    and KV-cached paths.  Runs against a scoped fresh cache so the
    counter assertions can't flake on test ordering (the process-wide
    counters see every other test's decodes)."""
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    cached_dcfg = _dcfg(cache_policy="prefix")
    with decode_cache_scope():
        d1 = Decoder(params, CFG, _dcfg())
        d1.generate(jax.random.PRNGKey(0), prompts)
        Decoder(params, CFG, cached_dcfg).generate(jax.random.PRNGKey(0),
                                                   prompts)
        before = decode_cache_info()
        d2 = Decoder(params, CFG, _dcfg())      # fresh but equal config
        d2.generate(jax.random.PRNGKey(1), prompts)
        Decoder(params, CFG, cached_dcfg).generate(jax.random.PRNGKey(1),
                                                   prompts)
        after = decode_cache_info()
        assert after.traces == before.traces, "recompiled on repeat decode"
        assert after.misses == before.misses, "rebuilt a cached runner"
        assert after.hits > before.hits


def test_cache_stats_reset_keeps_runners(model):
    """reset_decode_cache_stats zeroes the counters without dropping
    compiled runners: the next identical decode is all hits, zero
    misses/traces — the hermetic baseline compile-count tests need."""
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    with decode_cache_scope():
        Decoder(params, CFG, _dcfg()).generate(jax.random.PRNGKey(0),
                                               prompts)
        assert decode_cache_info().misses > 0
        reset_decode_cache_stats()
        zeroed = decode_cache_info()
        assert (zeroed.hits, zeroed.misses, zeroed.traces) == (0, 0, 0)
        assert zeroed.runners > 0, "reset must not drop compiled runners"
        Decoder(params, CFG, _dcfg()).generate(jax.random.PRNGKey(1),
                                               prompts)
        after = decode_cache_info()
        assert after.misses == 0 and after.traces == 0
        assert after.hits > 0


def test_cache_scope_restores_previous_cache(model):
    params, _ = model
    prompts = jnp.full((1, 4), 2, jnp.int32)
    outer = decode_cache_info()
    with decode_cache_scope() as scoped:
        Decoder(params, CFG, _dcfg(gen_length=8, block_size=8,
                                   steps=8)).generate(
            jax.random.PRNGKey(0), prompts)
        assert scoped.info().misses > 0
    # the scope's work never touched the process-wide counters
    assert decode_cache_info() == outer


def test_cache_entry_evicted_when_params_dropped():
    """New params after GC must not leak the old entry: the cache keys
    weakly on the weights' identity and runners never bake them in."""
    cache = RunnerCache()                      # private cache: no
    prompts = jnp.full((1, 4), 2, jnp.int32)   # interference from fixtures
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8)
    p1 = init_model(jax.random.PRNGKey(1), CFG)
    Decoder(p1, CFG, dcfg, cache=cache).generate(jax.random.PRNGKey(0),
                                                 prompts)
    assert cache.info().entries == 1
    del p1
    gc.collect()
    assert cache.info().entries == 0, "dropped params still cached"
    p2 = init_model(jax.random.PRNGKey(2), CFG)
    Decoder(p2, CFG, dcfg, cache=cache).generate(jax.random.PRNGKey(0),
                                                 prompts)
    assert cache.info().entries == 1


def test_cache_evicts_when_any_leaf_dropped():
    """Eviction must anchor on EVERY params leaf, not just the first: the
    key is a tuple of leaf ids, which are only unique while the leaves
    are alive — if a non-first leaf dies (partial weight swap) while leaf
    0 survives, a recycled id could alias a stale entry into a false
    cache hit.  First finalizer wins."""
    cache = RunnerCache()
    prompts = jnp.full((1, 4), 2, jnp.int32)
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8)
    p1 = init_model(jax.random.PRNGKey(1), CFG)
    leaf0 = jax.tree.leaves(p1)[0]    # noqa: F841 — held alive on purpose
    assert len(jax.tree.leaves(p1)) > 1, "test needs a multi-leaf pytree"
    Decoder(p1, CFG, dcfg, cache=cache).generate(jax.random.PRNGKey(0),
                                                 prompts)
    assert cache.info().entries == 1
    del p1                       # every leaf except leaf0 dies ...
    gc.collect()
    assert cache.info().entries == 0, \
        "non-first leaf died but the entry survived"
    del leaf0                    # ... and the stale finalizers are
    gc.collect()                 # detached: leaf0's can't double-evict
    assert cache.info().entries == 0


def test_cache_evicts_model_fn_entries_too(model):
    params, _ = model
    cache = RunnerCache()
    prompts = jnp.full((1, 4), 2, jnp.int32)
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8)
    mf = jax.jit(lambda x: forward(params, x, CFG)[0])
    Decoder(mf, CFG, dcfg, cache=cache).generate(jax.random.PRNGKey(0),
                                                 prompts)
    assert cache.info().entries == 1
    del mf
    gc.collect()
    assert cache.info().entries == 0


# --------------------------------------------------------------------------
# streaming: on_block_committed fires once per block, in order, under all
# three drivers (host / per-block fused / whole-request io_callback)
# --------------------------------------------------------------------------

DRIVERS = {
    "host": dict(fused_loop=False),
    "block": dict(fused_loop=True, fused_blocks=False),
    "request": dict(fused_loop=True, fused_blocks=True),
}


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_on_block_committed_ordering(model, driver):
    """Exactly num_blocks events, in block order, with the right (lo, hi)
    — including the whole-request driver, where the callback arrives via
    an ordered io_callback from inside the single compiled dispatch."""
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    events = []
    dec = Decoder(params, CFG, _dcfg(gen_length=16, block_size=4,
                                     **DRIVERS[driver]))
    out, _ = dec.generate(
        jax.random.PRNGKey(0), prompts,
        on_block_committed=lambda blk, lo, hi, x: events.append(
            (blk, lo, hi, bool((np.asarray(x)[:, lo:hi]
                                != CFG.mask_token_id).all()))))
    assert [(e[0], e[1], e[2]) for e in events] == \
        [(0, 6, 10), (1, 10, 14), (2, 14, 18), (3, 18, 22)]
    # at each event the just-committed block is fully decoded
    assert all(e[3] for e in events)


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_on_block_committed_cached_path(model, driver):
    """The streaming contract is policy-independent: the KV-cached path
    delivers the same num_blocks ordered events with correct bounds under
    every driver (the whole-request driver folds the refreshes into the
    same dispatch the io_callbacks fire from)."""
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    events = []
    dec = Decoder(params, CFG, _dcfg(cache_policy="prefix",
                                     **DRIVERS[driver]))
    dec.generate(jax.random.PRNGKey(0), prompts,
                 on_block_committed=lambda blk, lo, hi, x:
                 events.append((blk, lo, hi)))
    assert events == [(0, 6, 14), (1, 14, 22)]


def test_streaming_and_plain_request_decodes_match(model):
    """The streaming whole-request variant (its own compiled program, with
    io_callbacks woven in) must not perturb the decode itself."""
    params, _ = model
    prompts = jnp.full((2, 6), 2, jnp.int32)
    dec = Decoder(params, CFG, _dcfg())
    out_plain, s_plain = dec.generate(jax.random.PRNGKey(0), prompts)
    out_stream, s_stream = dec.generate(jax.random.PRNGKey(0), prompts,
                                        on_block_committed=lambda *a: None)
    np.testing.assert_array_equal(np.asarray(out_plain),
                                  np.asarray(out_stream))
    assert s_plain.steps == s_stream.steps


def test_model_fn_decoder_rejects_cache_policy(model):
    _, model_fn = model
    with pytest.raises(ValueError, match="params"):
        Decoder(model_fn, CFG, _dcfg(cache_policy="prefix")).generate(
            jax.random.PRNGKey(0), jnp.full((1, 4), 2, jnp.int32))
