"""The cache-policy axis end to end: three-driver bit-parity under both
approximate policies for every registered strategy, policy validation at
every trust boundary (DecodeConfig, Decoder, ServingEngine.submit),
one-executable-per-policy compile accounting, the dual policy's forward
saving, and the engine's refusal to co-batch requests with different
effective cache policies."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DecodeConfig, get_config
from repro.core import (Decoder, decode_cache_info, decode_cache_scope,
                        validate_cache_policy)
from repro.models.model import init_model
from repro.serving import ServingEngine

CFG = get_config("llada-8b").reduced()

STRATEGIES = ["random", "probability", "margin", "entropy", "eb", "wino",
              "fdm", "fdm_a", "wino_r", "extrapolate"]

DRIVERS = {
    "host": dict(fused_loop=False),
    "block": dict(fused_loop=True, fused_blocks=False),
    "request": dict(fused_loop=True, fused_blocks=True),
}

POLICIES = ("prefix", "dual")


@pytest.fixture(scope="module")
def params():
    """Untrained tiny model — cache mechanics, not output quality."""
    return init_model(jax.random.PRNGKey(0), CFG)


def _dcfg(**over):
    base = dict(gen_length=16, block_size=8, steps=16, k=2, k1=2,
                strategy="probability")
    base.update(over)
    return DecodeConfig(**base)


def _prompt(length, fill=3):
    return np.full((length,), fill, np.int32)


# --------------------------------------------------------------------------
# parity: host ≡ per-block fused ≡ whole-request fused, per policy
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_three_driver_parity_per_policy(params, strategy, policy):
    """The cache changes *what* the model computes (windowed forwards over
    a frozen cache), but it must not change it differently per driver:
    tokens and step counts stay bit-identical across all three drivers
    under a fixed policy, and forward accounting agrees to float
    precision (refreshes counted host-side vs in-scan)."""
    prompts = jnp.full((3, 6), 2, jnp.int32)
    dcfg = _dcfg(strategy=strategy, cache_policy=policy)
    runs = {}
    for name, over in DRIVERS.items():
        runs[name] = Decoder(params, CFG,
                             dataclasses.replace(dcfg, **over)).generate(
            jax.random.PRNGKey(0), prompts)
    out_ref, s_ref = runs["host"]
    for name in ("block", "request"):
        out, s = runs[name]
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref),
                                      err_msg=f"{strategy}/{policy}/{name}")
        assert s.steps == s_ref.steps, (strategy, policy, name)
        assert s.forward_equivalents == \
            pytest.approx(s_ref.forward_equivalents), (strategy, policy,
                                                       name)
    assert not (np.asarray(out_ref) == CFG.mask_token_id).any()


def test_dual_policy_reduces_forward_cost(params):
    """The acceptance criterion in miniature: the dual policy's windowed
    steps (block_size/total of a full forward each) must cost measurably
    fewer forward-equivalents than uncached decoding of the same request,
    refreshes included."""
    prompts = jnp.full((2, 6), 2, jnp.int32)
    fwd = {}
    for policy in ("none", "dual"):
        _, stats = Decoder(params, CFG,
                           _dcfg(cache_policy=policy)).generate(
            jax.random.PRNGKey(0), prompts)
        fwd[policy] = stats.forward_equivalents
    assert fwd["dual"] < fwd["none"]


# --------------------------------------------------------------------------
# validation at every boundary
# --------------------------------------------------------------------------

def test_unknown_cache_policy_rejected_at_config():
    with pytest.raises(ValueError, match="cache_policy"):
        _dcfg(cache_policy="lru")


def test_dual_requires_block_refresh():
    """dual freezes out-of-block K/V; without per-block refreshes the
    whole canvas outside block 0 would decode against the prefill — the
    config rejects the combination rather than silently degrading."""
    with pytest.raises(ValueError, match="cache_refresh"):
        _dcfg(cache_policy="dual", cache_refresh="off")
    # prefix + refresh-off is a legal (cheapest, most approximate) point
    _dcfg(cache_policy="prefix", cache_refresh="off")


@pytest.mark.parametrize("name", ["xlstm-125m", "hymba-1.5b"])
def test_recurrent_archs_reject_cache_policies(name):
    """ssm/hybrid state is a running reduction — there are no per-position
    K/V rows to scatter into, so only cache_policy='none' is servable."""
    cfg = get_config(name).reduced()
    validate_cache_policy(cfg, _dcfg())          # none: always fine
    for policy in POLICIES:
        with pytest.raises(ValueError, match="attention-backed"):
            validate_cache_policy(cfg, _dcfg(cache_policy=policy))


def test_model_fn_decoder_rejects_cached_generate(params):
    """The cache captures per-layer K/V, which needs params — a Decoder
    wrapped around a bare model_fn must refuse, at generate(), with an
    actionable error."""
    from repro.models.model import forward
    model_fn = jax.jit(lambda x: forward(params, x, CFG)[0])
    dec = Decoder(model_fn, CFG, _dcfg(cache_policy="prefix"))
    with pytest.raises(ValueError, match="params"):
        dec.generate(jax.random.PRNGKey(0), jnp.full((2, 6), 2, jnp.int32))


# --------------------------------------------------------------------------
# compile accounting: one executable per strategy × shape × policy
# --------------------------------------------------------------------------

def test_zero_recompiles_per_policy(params):
    """Each policy traces its own executable on first use; repeat decodes
    under any already-seen policy must neither build nor trace anything —
    the cache key includes the policy, so policies never evict each
    other."""
    prompts = jnp.full((2, 6), 2, jnp.int32)
    with decode_cache_scope():
        for policy in ("none",) + POLICIES:
            Decoder(params, CFG, _dcfg(cache_policy=policy)).generate(
                jax.random.PRNGKey(0), prompts)
        before = decode_cache_info()
        for policy in ("none",) + POLICIES:     # fresh Decoders, same keys
            Decoder(params, CFG, _dcfg(cache_policy=policy)).generate(
                jax.random.PRNGKey(1), prompts)
        after = decode_cache_info()
        assert after.traces == before.traces, "recompiled on repeat decode"
        assert after.misses == before.misses, "rebuilt a cached runner"
        assert after.hits > before.hits


# --------------------------------------------------------------------------
# serving: per-request policy overrides and batch isolation
# --------------------------------------------------------------------------

def test_engine_rejects_bad_cache_policy_at_submit(params):
    engine = ServingEngine(params, CFG, _dcfg(), max_batch=4,
                           length_bucket=8)
    with pytest.raises(ValueError, match="cache_policy"):
        engine.submit(_prompt(6), cache_policy="lru")
    assert engine.queue_depth == 0               # nothing bad was queued


def test_mixed_cache_policies_never_share_a_batch(params):
    """Same prompt bucket, same strategy, different cache policy →
    separate batches (the cached runner attends over cache state the
    uncached runner does not have; co-batching would decode one request
    under another's policy)."""
    engine = ServingEngine(params, CFG, _dcfg(), max_batch=4,
                           length_bucket=8)
    a = engine.submit(_prompt(6))
    b = engine.submit(_prompt(6), cache_policy="prefix")
    c = engine.submit(_prompt(6))
    first = engine.step()
    assert sorted(first) == sorted([a, c])       # same-policy pair
    second = engine.step()
    assert second == [b]
    # b decoded under its requested policy, bit-identical to direct
    direct, _ = Decoder(params, CFG,
                        _dcfg(cache_policy="prefix")).generate(
        jax.random.PRNGKey(7), np.asarray([_prompt(6)]))
    np.testing.assert_array_equal(engine.result(b).result,
                                  np.asarray(direct)[0])
