"""Graceful shutdown + client resilience + request-body limits.

The shutdown-race regression (a close() racing an in-flight batch must
deliver that batch's REAL terminal events), scheduler drain semantics
(admission stops → 503, bounded by the drain deadline, leftovers get
``shutdown``), the blocking client's retry policy, and the HTTP body
limits (Content-Length cap before buffering, chunked rejection,
Retry-After on backpressure).
"""
import asyncio
import socket
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import (DecodeConfig, RouterConfig, ServerConfig,
                           SupervisorConfig, get_config)
from repro.models.model import init_model
from repro.serving import (AsyncScheduler, ModelRouter,
                           SchedulerDrainingError, ServerError,
                           ServerThread, ServingClient, ServingEngine)

CFG = get_config("llada-8b").reduced()
DCFG = DecodeConfig(gen_length=16, block_size=8, steps=16,
                    strategy="probability")


@pytest.fixture(scope="module")
def params():
    return init_model(jax.random.PRNGKey(0), CFG)


def _engine(params, **kw):
    kw.setdefault("max_batch", 4)
    return ServingEngine(params, CFG, DCFG, **kw)


def _prompt(i=0):
    return np.asarray([3, 5, 2, 7, 4, 6 + i], np.int32)


# --------------------------------------------------------------------------
# the shutdown race: close() vs an in-flight batch
# --------------------------------------------------------------------------

def test_close_during_decode_keeps_real_terminal_events(params):
    """Regression: ``close()`` while a batch is in flight must NOT stamp
    the in-flight streams with ``shutdown`` — the batch completes and
    its requests get their real ``done`` events (the old code emitted
    shutdown to every unfinished stream, losing the batch's results)."""
    async def main():
        sched = AsyncScheduler(_engine(params))
        await sched.start()
        rid = sched.submit(_prompt())
        events = sched.events(rid)
        first = await anext(events)
        assert first["type"] == "block"     # decode is in flight NOW
        await sched.close()                 # races the running batch
        rest = [e async for e in events]
        finals = [e for e in rest if e.get("final")]
        assert len(finals) == 1
        assert finals[0]["type"] == "done"  # real result, not shutdown
        assert finals[0]["tokens"]          # with the decoded tokens

    asyncio.run(main())


def test_close_stamps_queued_requests_with_shutdown(params):
    """The complement: requests still QUEUED at close() (worker never
    started) end with exactly one terminal ``shutdown`` event."""
    async def main():
        sched = AsyncScheduler(_engine(params))
        rids = [sched.submit(_prompt(i)) for i in range(3)]
        await sched.close()
        for rid in rids:
            events = [e async for e in sched.events(rid)]
            assert [e["type"] for e in events] == ["shutdown"]
            assert events[-1]["final"] is True

    asyncio.run(main())


# --------------------------------------------------------------------------
# drain: admission stops, backlog finishes, deadline bounds the wait
# --------------------------------------------------------------------------

def test_drain_finishes_backlog_and_blocks_admission(params):
    async def main():
        sched = AsyncScheduler(
            _engine(params),
            svcfg=SupervisorConfig(drain_deadline_s=60.0))
        await sched.start()
        rids = [sched.submit(_prompt(i)) for i in range(2)]
        drain = asyncio.create_task(sched.drain())
        await asyncio.sleep(0)              # drain flips _draining
        assert sched.health == "draining"
        with pytest.raises(SchedulerDrainingError):
            sched.submit(_prompt(9))
        await drain
        for rid in rids:                    # backlog completed for real
            events = [e async for e in sched.events(rid)]
            assert events[-1]["type"] == "done"
        assert sched.health == "shutdown"

    asyncio.run(main())


def test_drain_deadline_stamps_leftovers_with_shutdown(params):
    """A drain whose deadline cannot cover the backlog stops anyway:
    whatever never decoded gets exactly one terminal ``shutdown``."""
    async def main():
        sched = AsyncScheduler(_engine(params))   # worker never started
        rids = [sched.submit(_prompt(i)) for i in range(3)]
        t0 = time.perf_counter()
        await sched.drain(deadline_s=0.05)
        assert time.perf_counter() - t0 < 5.0     # bounded, not hung
        for rid in rids:
            events = [e async for e in sched.events(rid)]
            finals = [e for e in events if e.get("final")]
            assert len(finals) == 1
            assert finals[0]["type"] == "shutdown"

    asyncio.run(main())


def test_server_drain_returns_503_with_retry_after(params):
    """Server-level drain over sockets: during the drain window new
    submissions answer 503 + Retry-After (retryable against a
    replacement), and the drain completes."""
    router = ModelRouter(RouterConfig())
    router.register("tiny", lambda: _engine(params))
    handle = ServerThread(router, ServerConfig(port=0)).start()
    try:
        client = ServingClient(handle.host, handle.port, max_retries=0)
        # cold submit: the first decode (compile included) holds the
        # drain open while we probe admission
        client.generate(_prompt().tolist(), wait=False)
        fut = asyncio.run_coroutine_threadsafe(
            handle.server.drain(30.0), handle._loop)
        saw_503 = False
        for _ in range(200):
            try:
                client.generate(_prompt(1).tolist(), wait=False)
            except ServerError as e:
                if e.status == 503:
                    saw_503 = True
                    assert e.retry_after is not None
                    break
            except OSError:
                break               # listener already closed
            time.sleep(0.01)
        fut.result(timeout=60)
        assert saw_503
    finally:
        handle.stop()


# --------------------------------------------------------------------------
# client retry policy (no sockets: the transport layer is stubbed)
# --------------------------------------------------------------------------

def _retry_client(**kw):
    kw.setdefault("max_retries", 2)
    kw.setdefault("backoff_base_s", 0.001)
    kw.setdefault("backoff_cap_s", 0.002)
    return ServingClient("127.0.0.1", 1, **kw)


def test_client_retries_connection_errors_then_succeeds():
    client = _retry_client()
    calls = []

    def flaky(method, path, body=None):
        calls.append(1)
        if len(calls) < 3:
            raise ConnectionResetError("mid-handshake")
        return {"ok": True}

    client._request_once = flaky
    assert client._request("GET", "/healthz") == {"ok": True}
    assert len(calls) == 3


def test_client_gives_up_after_max_retries():
    client = _retry_client(max_retries=1)
    calls = []

    def dead(method, path, body=None):
        calls.append(1)
        raise ConnectionRefusedError("down")

    client._request_once = dead
    with pytest.raises(ConnectionRefusedError):
        client._request("GET", "/healthz")
    assert len(calls) == 2                  # first try + one retry


def test_client_retries_429_honoring_retry_after():
    client = _retry_client()
    calls = []

    def busy(method, path, body=None):
        calls.append(1)
        if len(calls) < 2:
            raise ServerError(429, "full", retry_after=0.0)
        return {"rid": 1}

    client._request_once = busy
    assert client._request("POST", "/v1/generate", {})["rid"] == 1
    assert len(calls) == 2


def test_client_never_retries_client_errors():
    client = _retry_client()
    calls = []

    def bad(method, path, body=None):
        calls.append(1)
        raise ServerError(400, "bad geometry")

    client._request_once = bad
    with pytest.raises(ServerError):
        client._request("POST", "/v1/generate", {})
    assert len(calls) == 1


def test_client_max_retries_zero_is_single_shot():
    client = _retry_client(max_retries=0)
    calls = []

    def busy(method, path, body=None):
        calls.append(1)
        raise ServerError(429, "full", retry_after=0.0)

    client._request_once = busy
    with pytest.raises(ServerError):
        client._request("POST", "/v1/generate", {})
    assert len(calls) == 1


def test_stream_retries_only_before_first_event():
    client = _retry_client()
    calls = []

    def flaky(path):
        calls.append(1)
        if len(calls) == 1:
            raise ConnectionResetError("pre-yield")
        yield ("done", {"type": "done", "final": True})

    client._stream_once = flaky
    events = list(client.stream(0))
    assert [name for name, _ in events] == ["done"]
    assert len(calls) == 2                  # pre-yield failure retried

    calls.clear()

    def mid_stream(path):
        calls.append(1)
        yield ("block", {"type": "block"})
        raise ConnectionResetError("mid-stream")

    client._stream_once = mid_stream
    with pytest.raises(ConnectionResetError):
        list(client.stream(0))
    assert len(calls) == 1                  # NEVER retried after a yield


# --------------------------------------------------------------------------
# request-body limits over raw sockets
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def limited_server(params):
    router = ModelRouter(RouterConfig())
    router.register("tiny", lambda: _engine(params))
    handle = ServerThread(router, ServerConfig(
        port=0, max_body_bytes=2048)).start()
    yield handle
    handle.stop()


def _raw_http(host, port, payload: bytes) -> bytes:
    with socket.create_connection((host, port), timeout=30) as s:
        s.sendall(payload)
        chunks = []
        while True:
            data = s.recv(4096)
            if not data:
                break
            chunks.append(data)
    return b"".join(chunks)


def test_oversized_body_is_413_before_buffering(limited_server):
    body = b"x" * 4096                      # 2x the cap
    req = (f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
           f"Content-Type: application/json\r\n"
           f"Content-Length: {len(body)}\r\n\r\n").encode() + body
    resp = _raw_http(limited_server.host, limited_server.port, req)
    assert resp.startswith(b"HTTP/1.1 413")
    assert b"too large" in resp


def test_chunked_body_is_rejected_413(limited_server):
    req = (b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
           b"Content-Type: application/json\r\n"
           b"Transfer-Encoding: chunked\r\n\r\n"
           b"5\r\nhello\r\n0\r\n\r\n")
    resp = _raw_http(limited_server.host, limited_server.port, req)
    assert resp.startswith(b"HTTP/1.1 413")
    assert b"chunked" in resp


def test_negative_content_length_is_400(limited_server):
    req = (b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
           b"Content-Length: -5\r\n\r\n")
    resp = _raw_http(limited_server.host, limited_server.port, req)
    assert resp.startswith(b"HTTP/1.1 400")


def test_oversized_get_body_is_also_capped(limited_server):
    """The cap is route-independent: a GET with an absurd declared body
    is refused the same way (every route shares _read_request)."""
    req = (b"GET /healthz HTTP/1.1\r\nHost: t\r\n"
           b"Content-Length: 999999\r\n\r\n")
    resp = _raw_http(limited_server.host, limited_server.port, req)
    assert resp.startswith(b"HTTP/1.1 413")


# --------------------------------------------------------------------------
# untested error paths: cancel-mid-block, deadline-during-decode,
# eviction racing an active stream
# --------------------------------------------------------------------------

def test_cancel_mid_block_cannot_preempt_and_result_arrives(params):
    async def main():
        sched = AsyncScheduler(_engine(params))
        await sched.start()
        rid = sched.submit(_prompt())
        events = sched.events(rid)
        first = await anext(events)
        assert first["type"] == "block"     # decoding now
        assert sched.cancel(rid) is False   # batch-synchronous: no
        rest = [e async for e in events]    # preemption, result lands
        assert rest[-1]["type"] == "done"
        assert sched.counters["cancelled"] == 0
        await sched.close()

    asyncio.run(main())


def test_deadline_expires_while_another_batch_decodes(params):
    """Deadlines bound QUEUE time: a request whose deadline lapses while
    the worker is busy with an earlier batch is reaped with a terminal
    ``expired`` event, never decoded; the busy batch is unaffected."""
    async def main():
        sched = AsyncScheduler(_engine(params))
        await sched.start()
        slow = sched.submit(_prompt())
        events = sched.events(slow)
        first = await anext(events)
        assert first["type"] == "block"     # slow batch in flight
        doomed = sched.submit(_prompt(1), deadline_s=0.001)
        terminal = await sched.result(doomed)
        assert terminal["type"] == "expired"
        rest = [e async for e in events]
        assert rest[-1]["type"] == "done"
        assert sched.counters["expired"] == 1
        await sched.close()

    asyncio.run(main())


def test_router_eviction_races_active_stream(params):
    """hot_swap from a foreign thread while a stream is live: the stream
    ends with exactly one terminal event (its real ``done`` if the batch
    completed, else ``shutdown`` — never a hang, never a dropped
    connection), and the model serves fresh requests afterwards."""
    router = ModelRouter(RouterConfig())
    router.register("tiny", lambda: _engine(params))
    handle = ServerThread(router, ServerConfig(port=0)).start()
    try:
        client = ServingClient(handle.host, handle.port)
        sub = client.generate(_prompt().tolist(), wait=False)
        events = []
        got_first = threading.Event()

        def consume():
            for name, event in client.stream(sub["rid"],
                                             model=sub["model"]):
                events.append((name, event))
                got_first.set()
            got_first.set()

        t = threading.Thread(target=consume)
        t.start()
        assert got_first.wait(timeout=120)
        router.hot_swap("tiny")             # foreign-thread eviction
        t.join(timeout=120)
        assert not t.is_alive()
        finals = [e for _, e in events if e.get("final")]
        assert len(finals) == 1
        assert finals[0]["type"] in ("done", "shutdown")
        # the swapped-in engine serves a fresh request end to end
        res = client.generate(_prompt(1).tolist(), wait=True)
        assert res["status"] == "ok"
    finally:
        handle.stop()
