"""Jaxpr-grain conformance (``repro.analysis.conformance``): seeded-bug
strategies trip each contract, every registered strategy passes across
both fused drivers, the conftest guard auto-checks test registrations,
and the full analyzer run over the live repo is clean."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import io_callback

from repro.analysis import (ConformanceError, assert_conforms,
                            check_strategy, conformance_findings)
from repro.core import (Strategy, available_strategies,
                        register_strategy, unregister_strategy)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _commit_all(x, active, model_fn):
    logits = model_fn(x)
    return jnp.where(active, jnp.argmax(logits, -1).astype(x.dtype), x)


class CountingStrategy(Strategy):
    """Clean carry-ful strategy: conforms on every contract."""

    name = "seeded-clean"

    def init_carry(self, cfg, dcfg):
        return jnp.zeros((), jnp.int32)

    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        return _commit_all(x, active, model_fn), carry + 1, 1


class GrowingCarryStrategy(CountingStrategy):
    """Seeded ANA101: the carry doubles every step — breaks the
    while_loop carry invariant on the first real request."""

    name = "seeded-grow"

    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        return _commit_all(x, active, model_fn), (carry, carry), 1


class DtypeDriftStrategy(CountingStrategy):
    """Seeded ANA101: same structure, drifting dtype (i32 -> f32)."""

    name = "seeded-dtype"

    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        new_x = _commit_all(x, active, model_fn)
        return new_x, carry + jnp.asarray(1.0, jnp.float32), 1


class BeginBlockLeakStrategy(CountingStrategy):
    """Seeded ANA101: begin_block swaps the carry's structure."""

    name = "seeded-beginblock"

    def begin_block(self, carry, x, in_block):
        return (carry,)


class CallbackStrategy(CountingStrategy):
    """Seeded ANA102: smuggles a host callback into the fused step."""

    name = "seeded-callback"

    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        io_callback(lambda *_: None, None, carry)
        return _commit_all(x, active, model_fn), carry + 1, 1


class BakedConstStrategy(CountingStrategy):
    """Seeded ANA103: closes over a weight-sized array, which bakes
    into the fused jaxpr as a constant."""

    name = "seeded-baked"

    def __init__(self):
        self.table = jnp.ones((400, 400), jnp.float32)   # 640 KB

    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        bias = (self.table.sum() * 0).astype(jnp.int32)
        return _commit_all(x, active, model_fn) + bias, carry + 1, 1


class F64Strategy(CountingStrategy):
    """Seeded ANA104: a strongly-typed numpy double in the carry math —
    invisible at x32 (canonicalized away), doubles FLOPs under x64."""

    name = "seeded-f64"

    def init_carry(self, cfg, dcfg):
        return jnp.zeros((), jnp.float32)

    def step(self, rng, carry, x, active, model_fn, cfg, dcfg, n):
        return _commit_all(x, active, model_fn), carry + np.float64(0.5), 1


def rules_of(strategy):
    return {f.rule for f in check_strategy(strategy)}


# --------------------------------------------------------------------------
# seeded bugs fire
# --------------------------------------------------------------------------

def test_growing_carry_detected():
    assert rules_of(GrowingCarryStrategy()) == {"ANA101"}


def test_dtype_drift_detected():
    found = check_strategy(DtypeDriftStrategy())
    assert {f.rule for f in found} == {"ANA101"}
    assert any("fixed-point" in f.message for f in found)


def test_begin_block_leak_detected():
    found = check_strategy(BeginBlockLeakStrategy())
    assert any(f.rule == "ANA101" and "begin_block" in f.message
               for f in found)


def test_callback_in_fused_detected():
    found = check_strategy(CallbackStrategy())
    assert {f.rule for f in found} == {"ANA102"}
    # flagged under BOTH fused drivers
    assert any("drive_block" in f.message for f in found)
    assert any("drive_request" in f.message for f in found)


def test_baked_const_detected():
    found = check_strategy(BakedConstStrategy())
    assert {f.rule for f in found} == {"ANA103"}
    assert any("constant" in f.message for f in found)
    # a roomier threshold clears it — the knob works
    assert check_strategy(BakedConstStrategy(),
                          const_bytes=1 << 20) == []


def test_f64_promotion_detected():
    found = check_strategy(F64Strategy())
    assert {f.rule for f in found} == {"ANA104"}


def test_clean_strategy_passes():
    assert check_strategy(CountingStrategy()) == []
    assert_conforms(CountingStrategy())       # and the raising wrapper


def test_assert_conforms_raises_with_rule_ids():
    with pytest.raises(ConformanceError, match="ANA101"):
        assert_conforms(GrowingCarryStrategy())


# --------------------------------------------------------------------------
# the real registry: all 10 strategies, both fused drivers
# --------------------------------------------------------------------------

def test_every_registered_strategy_conforms():
    names = available_strategies()
    assert len(names) >= 10
    findings = conformance_findings(names)
    assert findings == [], [f.message for f in findings]


# --------------------------------------------------------------------------
# conftest guard
# --------------------------------------------------------------------------

def test_guard_checks_strategies_registered_by_tests():
    # the autouse guard conformance-checks this at teardown; a clean
    # strategy must sail through even though it is unregistered again
    register_strategy(CountingStrategy(), replace=True)
    unregister_strategy("seeded-clean")


@pytest.mark.no_conformance
def test_guard_marker_opts_out_for_broken_strategies():
    register_strategy(GrowingCarryStrategy(), replace=True)
    unregister_strategy("seeded-grow")


# --------------------------------------------------------------------------
# tier-1 gate: the live repo is clean (all three grains, the CI scope)
# --------------------------------------------------------------------------

def test_live_repo_has_zero_unbaselined_findings(capsys):
    from repro.analysis.cli import main
    rc = main([os.path.join(REPO, d)
               for d in ("src", "tools", "benchmarks", "examples")]
              + ["--baseline",
                 os.path.join(REPO, "tools", "repro_lint_baseline.txt")])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "clean" in out
    # the one honored suppression prints its rationale
    assert "sampler.py" in out and "rationale" in out


def test_live_repo_concurrency_grain_is_clean(capsys):
    """The new grain alone, over the full CI scope — a tighter gate
    than the combined run because it must pass with ZERO baselined
    concurrency findings (no debt in the serving stack)."""
    from repro.analysis.cli import main
    rc = main([os.path.join(REPO, d)
               for d in ("src", "tools", "benchmarks", "examples")]
              + ["--grain", "conc", "--only-rules",
                 "ANA201,ANA202,ANA203,ANA204,ANA205",
                 "--baseline", os.path.join(REPO, "tools",
                                            "repro_lint_baseline.txt")])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "baselined" not in out
