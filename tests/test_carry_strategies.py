"""The carry-ful builtin strategies (core/wino.py, core/extrapolate.py):
three-driver parity for plain and cached decoding, revocation / skipped-
forward accounting consistency, schedule-overrun (net-commit) geometry,
and the serving-engine stats plumbing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DecodeConfig, get_config
from repro.core import Decoder
from repro.models.model import init_model
from repro.serving import ServingEngine

CFG = get_config("llada-8b").reduced()

DRIVERS = {
    "host": dict(fused_loop=False),
    "block": dict(fused_loop=True, fused_blocks=False),
    "request": dict(fused_loop=True, fused_blocks=True),
}

# the untrained tiny model's confidences sit near 1/vocab, so knobs that
# exercise each mechanism must be forced: extrap_tau=0.0 makes every
# observed position's trajectory qualify (skips fire), wino_revoke_tau
# high makes every pending commit fail verification (revocations fire)
SKIP_KNOBS = dict(extrap_tau=0.0, extrap_min_obs=1)
REVOKE_KNOBS = dict(wino_revoke_tau=0.99, wino_revoke_budget=4)


@pytest.fixture(scope="module")
def model():
    params = init_model(jax.random.PRNGKey(0), CFG)
    return params


def _dcfg(**over):
    base = dict(gen_length=16, block_size=8, steps=16,
                strategy="probability")
    base.update(over)
    return DecodeConfig(**base)


def _run(params, dcfg, prompts=None, cached=False):
    prompts = prompts if prompts is not None \
        else jnp.full((3, 6), 2, jnp.int32)
    if cached:
        dcfg = dataclasses.replace(dcfg, cache_policy="prefix")
    out, stats = Decoder(params, CFG, dcfg).generate(jax.random.PRNGKey(0),
                                                     prompts)
    return np.asarray(out), stats


# --------------------------------------------------------------------------
# parity: both carry-ful strategies, all three plain drivers, bit-for-bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,knobs", [
    ("wino_r", REVOKE_KNOBS), ("extrapolate", SKIP_KNOBS)])
def test_plain_three_driver_parity(model, strategy, knobs):
    runs = {}
    for name, over in DRIVERS.items():
        runs[name] = _run(model, _dcfg(strategy=strategy, **knobs, **over))
    out_ref, s_ref = runs["host"]
    assert not (out_ref == CFG.mask_token_id).any()
    for name in ("block", "request"):
        out, s = runs[name]
        np.testing.assert_array_equal(out, out_ref, err_msg=name)
        assert s.steps == s_ref.steps, name
        assert s.forward_equivalents == \
            pytest.approx(s_ref.forward_equivalents), name
        assert s.revocations == s_ref.revocations, name
        assert s.skipped_forwards == s_ref.skipped_forwards, name


@pytest.mark.parametrize("strategy,knobs", [
    ("wino_r", REVOKE_KNOBS), ("extrapolate", SKIP_KNOBS)])
def test_cached_fused_host_parity(model, strategy, knobs):
    """The positional carry is sliced to the live window and written back
    per block — identically under the fused and host cached drivers."""
    outs = []
    for fused in (True, False):
        dcfg = _dcfg(strategy=strategy, fused_loop=fused, **knobs)
        outs.append(_run(model, dcfg, cached=True))
    (out_f, s_f), (out_h, s_h) = outs
    np.testing.assert_array_equal(out_f, out_h)
    assert not (out_f == CFG.mask_token_id).any()
    assert s_f.steps == s_h.steps
    assert s_f.forward_equivalents == pytest.approx(s_h.forward_equivalents)
    assert s_f.revocations == s_h.revocations
    assert s_f.skipped_forwards == s_h.skipped_forwards


# --------------------------------------------------------------------------
# accounting: the new SampleStats counters sum consistently
# --------------------------------------------------------------------------

@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_extrapolate_skip_accounting(model, driver):
    """Every step either pays one forward or skips one, so on the plain
    path steps == forward_equivalents + skipped_forwards — and with the
    threshold floored, skips genuinely happen."""
    dcfg = _dcfg(strategy="extrapolate", **SKIP_KNOBS, **DRIVERS[driver])
    _, s = _run(model, dcfg)
    assert s.skipped_forwards > 0
    assert s.steps == pytest.approx(
        s.forward_equivalents + s.skipped_forwards)


def test_extrapolate_never_skipping_matches_vanilla(model):
    """With an unreachable threshold the strategy IS vanilla confidence
    decoding — bit-identical to "probability", zero skips.  This is the
    controlled-baseline property the ablation benchmark relies on."""
    out_e, s_e = _run(model, _dcfg(strategy="extrapolate", extrap_tau=1.1))
    out_p, s_p = _run(model, _dcfg(strategy="probability"))
    np.testing.assert_array_equal(out_e, out_p)
    assert s_e.skipped_forwards == 0
    assert s_e.steps == s_p.steps
    assert s_e.forward_equivalents == pytest.approx(s_p.forward_equivalents)


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_wino_r_revocation_accounting(model, driver):
    """wino_r pays exactly one forward per step (the stateless baseline
    pays two), revokes within its per-example budget, and still resolves
    every mask."""
    b = 3
    dcfg = _dcfg(strategy="wino_r", **REVOKE_KNOBS, **DRIVERS[driver])
    out, s = _run(model, dcfg, prompts=jnp.full((b, 6), 2, jnp.int32))
    assert not (out == CFG.mask_token_id).any()
    assert s.forward_equivalents == pytest.approx(s.steps)
    assert 0 < s.revocations <= b * REVOKE_KNOBS["wino_revoke_budget"]
    # each revocation un-commits one token that a later step re-commits,
    # so the decode runs extra steps beyond the 16 scheduled
    assert s.steps > 16


def test_wino_r_zero_budget_never_revokes(model):
    dcfg = _dcfg(strategy="wino_r", wino_revoke_tau=0.99,
                 wino_revoke_budget=0)
    out, s = _run(model, dcfg)
    assert s.revocations == 0
    assert s.steps == 16
    assert not (out == CFG.mask_token_id).any()


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_wino_r_overruns_remainder_schedule_safely(model, driver):
    """Net-commit geometry: revocation pushes blocks past their schedule
    rows; the rows pad with their final width (never zero), so overrun
    steps keep committing and the decode still terminates mask-free —
    the zero-padded seed schedule would stall until the safety cap."""
    dcfg = _dcfg(gen_length=16, block_size=4, steps=10, strategy="wino_r",
                 **REVOKE_KNOBS, **DRIVERS[driver])
    out, s = _run(model, dcfg, prompts=jnp.full((2, 6), 2, jnp.int32))
    assert not (out == CFG.mask_token_id).any()
    assert s.revocations > 0
    assert s.steps < 4 * 4 * 4       # well inside num_blocks · bs·4


def test_carry_ful_strategies_reject_shapeless_init_carry(model):
    """A positional carry needs the canvas shape; the shapeless
    ``init_carry`` entry point must refuse loudly, not silently
    mis-decode."""
    from repro.core.strategies import resolve_strategy
    for name in ("wino_r", "extrapolate"):
        strat = resolve_strategy(name)
        with pytest.raises(TypeError, match="per-decode"):
            strat.init_carry(CFG, _dcfg())


# --------------------------------------------------------------------------
# serving engine: the new counters are pro-rated like forwards
# --------------------------------------------------------------------------

def test_serving_pro_rates_skipped_forwards(model):
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8,
                 strategy="extrapolate", **SKIP_KNOBS)
    engine = ServingEngine(model, CFG, dcfg, max_batch=4, length_bucket=8)
    rids = [engine.submit(np.full((6,), 3, np.int32)) for _ in range(3)]
    engine.run_until_idle()
    stats = [engine.result(r).stats for r in rids]
    total = sum(s.skipped_forwards for s in stats)
    assert total > 0
    # batch total split evenly over the 3 real requests
    assert stats[0].skipped_forwards == pytest.approx(total / 3)
    summ = engine.summary()
    assert summ["skipped_forwards"] == pytest.approx(total)
    assert summ["revocations"] == 0
    for s in stats:
        assert s.steps == pytest.approx(
            s.forward_equivalents * 3 + s.skipped_forwards * 3)


def test_serving_pro_rates_revocations(model):
    dcfg = _dcfg(gen_length=8, block_size=8, steps=8, strategy="wino_r",
                 **REVOKE_KNOBS)
    engine = ServingEngine(model, CFG, dcfg, max_batch=2, length_bucket=8)
    rids = [engine.submit(np.full((6,), 3, np.int32)) for _ in range(2)]
    engine.run_until_idle()
    stats = [engine.result(r).stats for r in rids]
    total = sum(s.revocations for s in stats)
    assert total > 0
    assert engine.summary()["revocations"] == pytest.approx(total)
