"""AST-grain rules of ``repro.analysis``: every rule fires on a seeded
bug snippet and stays quiet on the closest clean variant, suppressions
require rationales, and the baseline machinery round-trips."""
import textwrap

import pytest

from repro.analysis import analyze_source
from repro.analysis.findings import RULES, Finding
from repro.analysis.suppressions import (apply_baseline,
                                         apply_suppressions,
                                         load_baseline,
                                         scan_suppressions,
                                         write_baseline)


def run(src, rule=None):
    fs = analyze_source("snippet.py", textwrap.dedent(src))
    return [f for f in fs if rule is None or f.rule == rule]


# --------------------------------------------------------------------------
# ANA001 — host syncs reachable from fused roots
# --------------------------------------------------------------------------

def test_host_sync_fires_in_fused_step():
    fs = run("""
        def fused_step(rng, carry, x):
            v = x.mean()
            return v.item()
    """, "ANA001")
    assert len(fs) == 1 and ".item()" in fs[0].message


def test_host_sync_fires_via_local_call_chain():
    fs = run("""
        import numpy as np

        def helper(x):
            return np.asarray(x)

        def drive_block(x):
            return helper(x)
    """, "ANA001")
    assert len(fs) == 1 and "np.asarray" in fs[0].message


def test_host_sync_fires_in_jit_and_while_loop_bodies():
    fs = run("""
        import jax

        @jax.jit
        def run(x):
            return float(x)

        def outer(x):
            def body(c):
                return bool(c)
            return jax.lax.while_loop(lambda c: True, body, x)
    """, "ANA001")
    assert {"float() on" in f.message or "bool() on" in f.message
            for f in fs} == {True}
    assert len(fs) == 2


def test_host_sync_quiet_outside_fused_reachability():
    # same syncs, but only reachable from plain host functions
    assert run("""
        import numpy as np

        def host_stats(x):
            return float(np.asarray(x).mean())

        def fused_step(rng, carry, x):
            return x
    """, "ANA001") == []


def test_host_sync_quiet_on_static_shape_coercion():
    assert run("""
        def fused_step(rng, carry, x):
            b = int(x.shape[0])
            return x[:b]
    """, "ANA001") == []


# --------------------------------------------------------------------------
# ANA002 — jit identity churn
# --------------------------------------------------------------------------

def test_jit_lambda_fires():
    fs = run("""
        import jax

        def make(params):
            return jax.jit(lambda x: x + 1)
    """, "ANA002")
    assert len(fs) == 1 and "lambda" in fs[0].message


def test_jit_in_loop_fires():
    fs = run("""
        import jax

        def sweep(fns, x):
            for fn in fns:
                x = jax.jit(fn)(x)
            return x
    """, "ANA002")
    assert len(fs) == 1 and "loop" in fs[0].message


def test_returned_nested_jit_fires():
    fs = run("""
        import jax

        def factory(params):
            @jax.jit
            def run(x):
                return x + params
            return run
    """, "ANA002")
    assert len(fs) == 1 and "factory" in fs[0].message


def test_runner_cache_builder_idiom_is_exempt():
    # core/decoder.py: the factory's name feeds `cache.get(…)`, which
    # guarantees one build per key — no churn
    assert run("""
        import jax

        def runner(self, key):
            def build():
                @jax.jit
                def run(x):
                    return x
                return run
            return self._cache.get(key, build)
    """, "ANA002") == []


def test_module_level_jit_is_clean():
    assert run("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("flag",))
        def kernel(x, flag=False):
            return x
    """, "ANA002") == []


# --------------------------------------------------------------------------
# ANA003 — PRNG key reuse
# --------------------------------------------------------------------------

def test_key_reuse_fires():
    fs = run("""
        import jax

        def sample(key, shape):
            a = jax.random.uniform(key, shape)
            b = jax.random.normal(key, shape)
            return a + b
    """, "ANA003")
    assert len(fs) == 1 and "'key'" in fs[0].message


def test_key_reuse_in_loop_without_rebind_fires():
    fs = run("""
        import jax

        def sample(key, n):
            out = []
            for _ in range(n):
                out.append(jax.random.uniform(key, (2,)))
            return out
    """, "ANA003")
    assert len(fs) == 1


def test_key_reuse_quiet_with_split():
    assert run("""
        import jax

        def sample(key, shape):
            key, k1 = jax.random.split(key)
            a = jax.random.uniform(k1, shape)
            key, k2 = jax.random.split(key)
            b = jax.random.normal(k2, shape)
            return a + b
    """, "ANA003") == []


def test_key_reuse_quiet_across_branches():
    # one branch runs, not both: no double consumption
    assert run("""
        import jax

        def sample(key, flag, shape):
            if flag:
                return jax.random.uniform(key, shape)
            else:
                return jax.random.normal(key, shape)
    """, "ANA003") == []


# --------------------------------------------------------------------------
# ANA004 — strong params refs in cache decorators
# --------------------------------------------------------------------------

def test_lru_cache_over_params_fires():
    fs = run("""
        import functools

        @functools.lru_cache(maxsize=8)
        def runner_for(params, shape):
            return params
    """, "ANA004")
    assert len(fs) == 1 and "params" in fs[0].message


def test_lru_cache_over_scalars_is_clean():
    assert run("""
        import functools

        @functools.lru_cache()
        def geometry(gen_length, block_size):
            return gen_length // block_size
    """, "ANA004") == []


# --------------------------------------------------------------------------
# ANA005 — blocking calls in async defs
# --------------------------------------------------------------------------

def test_blocking_sleep_in_async_fires():
    fs = run("""
        import time

        async def handler(req):
            time.sleep(0.1)
            return req
    """, "ANA005")
    assert len(fs) == 1 and "time.sleep" in fs[0].message


def test_blocking_open_in_async_fires():
    fs = run("""
        async def handler(path):
            with open(path) as fh:
                return fh.name
    """, "ANA005")
    assert len(fs) == 1 and "open()" in fs[0].message


def test_async_clean_and_executor_exempt():
    # awaited sleeps and nested sync defs (run_in_executor bodies) are
    # exactly how the scheduler is written — must stay quiet
    assert run("""
        import asyncio
        import time

        async def handler(loop, req):
            await asyncio.sleep(0.1)

            def _work():
                time.sleep(0.5)
                return req
            return await loop.run_in_executor(None, _work)
    """, "ANA005") == []


# --------------------------------------------------------------------------
# ANA006 — unordered io_callback
# --------------------------------------------------------------------------

def test_unordered_io_callback_fires():
    fs = run("""
        from jax.experimental import io_callback

        def stream(emit, blk, canvas):
            io_callback(emit, None, blk, canvas)
    """, "ANA006")
    assert len(fs) == 1 and "ordered" in fs[0].message


def test_ordered_io_callback_is_clean():
    assert run("""
        from jax.experimental import io_callback

        def stream(emit, blk, canvas):
            io_callback(emit, None, blk, canvas, ordered=True)
    """, "ANA006") == []


# --------------------------------------------------------------------------
# ANA000 + suppression mechanics
# --------------------------------------------------------------------------

def test_suppression_without_rationale_is_a_finding():
    sups, problems = scan_suppressions("snippet.py", textwrap.dedent("""
        x = 1  # repro-lint: ignore[ANA001]
    """))
    assert len(problems) == 1 and problems[0].rule == "ANA000"
    assert "rationale" in problems[0].message


def test_suppression_with_rationale_silences_and_prints():
    src = textwrap.dedent("""
        import jax

        def make(params):
            return jax.jit(lambda x: x)  # repro-lint: ignore[ANA002] -- test double
    """)
    sups, problems = scan_suppressions("snippet.py", src)
    assert problems == []
    findings = analyze_source("snippet.py", src)
    active, suppressed = apply_suppressions(findings, {"snippet.py": sups})
    assert active == []
    assert len(suppressed) == 1
    assert suppressed[0].suppressed == "test double"


def test_suppression_comment_block_covers_next_code_line():
    src = textwrap.dedent("""
        import jax

        def make(params):
            # repro-lint: ignore[ANA002] -- wraps a decorated def below
            # (continuation line of the comment block)
            f = jax.jit(lambda x: x)
            return f
    """)
    sups, _ = scan_suppressions("snippet.py", src)
    findings = analyze_source("snippet.py", src)
    active, suppressed = apply_suppressions(findings, {"snippet.py": sups})
    assert active == [] and len(suppressed) == 1


def test_wildcard_suppression_covers_every_rule():
    src = ("import time\nasync def h():\n    time.sleep(1)  "
           "# repro-lint: ignore[*] -- seeded test fixture\n")
    sups, _ = scan_suppressions("snippet.py", src)
    active, suppressed = apply_suppressions(
        analyze_source("snippet.py", src), {"snippet.py": sups})
    assert active == [] and len(suppressed) == 1


# --------------------------------------------------------------------------
# baseline round-trip
# --------------------------------------------------------------------------

def test_baseline_roundtrip(tmp_path):
    f1 = Finding("a.py", 3, "ANA001", "sync in fused", "error")
    f2 = Finding("b.py", 9, "ANA002", "jit churn", "error")
    path = str(tmp_path / "baseline.txt")
    assert write_baseline(path, [f1, f2]) == 2
    baseline = load_baseline(path)
    # line drift must not invalidate the baseline
    drifted = Finding("a.py", 30, "ANA001", "sync in fused", "error")
    active, known = apply_baseline([drifted, f2], baseline)
    assert active == [] and len(known) == 2
    fresh = Finding("c.py", 1, "ANA001", "new sync", "error")
    active, known = apply_baseline([fresh], baseline)
    assert active == [fresh]


def test_every_ast_rule_has_catalog_entry():
    seen = {f.rule for f in run("""
        import functools, time, jax
        from jax.experimental import io_callback

        def fused_step(rng, carry, x):
            return x.item()

        def churn(params):
            return jax.jit(lambda x: x)

        def reuse(key):
            a = jax.random.uniform(key, (2,))
            return a + jax.random.normal(key, (2,))

        @functools.lru_cache()
        def pin(params):
            return params

        async def block():
            time.sleep(1)

        def stream(emit, x):
            io_callback(emit, None, x)
    """)}
    assert seen == {"ANA001", "ANA002", "ANA003", "ANA004", "ANA005",
                    "ANA006"}
    assert seen <= set(RULES)
