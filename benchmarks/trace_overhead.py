"""Step-telemetry overhead: trace=off vs trace=on, same decode.

The TraceBuffer rides the fused-loop carry — fixed-shape writes, no
callbacks, one ``device_get`` per decode — so the overhead budget is
small and gated: trace=on must keep ≥95% of trace=off steps/sec on the
dispatch-bound ``loop-bound`` model (the regime where any extra carry
traffic would show).  When ``BENCH_decode_loop.json`` exists for this
backend, trace=on is additionally gated against the recorded
whole-request baseline — telemetry may not eat the fused-driver win.

``REPRO_TRACE_OUT=<path>``: also export one traced decode as Chrome
trace-event JSON (the CI bench-smoke job uploads it as an artifact, so
every CI run leaves an openable trace of the exact code it tested).

``PYTHONPATH=src python -m benchmarks.trace_overhead``
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from benchmarks.loop_overhead import (GEN, BLOCK, PROMPT_LEN, REPEATS,
                                      MODELS, OUT_PATH as LOOP_BASELINE)
from repro.configs import DecodeConfig, get_config
from repro.core import Decoder
from repro.models.model import init_model

MAX_OVERHEAD = 0.05          # trace=on keeps ≥95% of trace=off steps/s


def _interleaved_steps_per_sec(dec_off, dec_on, prompts,
                               repeats: int = REPEATS):
    """Best-of-N for BOTH decoders, alternating off/on each round: the
    two sides see the same machine-load drift, so the ratio measures the
    telemetry, not which window a cron job landed in."""
    dec_off.generate(jax.random.PRNGKey(0), prompts)     # compile
    dec_on.generate(jax.random.PRNGKey(0), prompts)
    best_off = best_on = 0.0
    for r in range(repeats):
        _, s = dec_off.generate(jax.random.PRNGKey(r), prompts)
        best_off = max(best_off, s.steps / max(s.wall_time, 1e-9))
        _, s = dec_on.generate(jax.random.PRNGKey(r), prompts)
        best_on = max(best_on, s.steps / max(s.wall_time, 1e-9))
    return best_off, best_on


def _export_chrome_trace(decoder, prompts, path: str) -> None:
    from repro.serving.tracing import Span, chrome_trace
    _, stats = decoder.generate(jax.random.PRNGKey(0), prompts)
    span = Span("decode", "decode", 0.0, max(stats.wall_time, 1e-6))
    with open(path, "w") as f:
        json.dump(chrome_trace(0, [span], stats.trace,
                               {"benchmark": "trace_overhead",
                                "steps": int(stats.steps)}), f)
    print(f"[wrote Chrome trace -> {path}]")


def run(strategy: str = "probability", fast: bool = False) -> List[Dict]:
    cfg = get_config("llada-8b").reduced(**MODELS["loop-bound"])
    params = init_model(jax.random.PRNGKey(0), cfg)
    base = DecodeConfig(gen_length=GEN, block_size=BLOCK, steps=GEN,
                        strategy=strategy)
    prompts = jnp.ones((1, PROMPT_LEN), jnp.int32)
    repeats = 3 if fast else REPEATS

    traced = Decoder(params, cfg,
                     dataclasses.replace(base, trace=True))
    off, on = _interleaved_steps_per_sec(
        Decoder(params, cfg, base), traced, prompts, repeats)
    ratio = on / max(off, 1e-9)
    rows = [{"model": "loop-bound", "strategy": strategy,
             "trace_off_steps_per_sec": round(off, 1),
             "trace_on_steps_per_sec": round(on, 1),
             "ratio": round(ratio, 3)}]
    print("\n== step-telemetry overhead: trace=off vs trace=on ==")
    print_table(rows, ["model", "strategy", "trace_off_steps_per_sec",
                       "trace_on_steps_per_sec", "ratio"])

    out = os.environ.get("REPRO_TRACE_OUT")
    if out:
        _export_chrome_trace(traced, prompts, out)

    assert ratio >= 1.0 - MAX_OVERHEAD, (
        f"trace overhead gate: trace=on {on:.1f} steps/s is "
        f"{(1 - ratio) * 100:.1f}% below trace=off {off:.1f} "
        f"(budget {MAX_OVERHEAD * 100:.0f}%)")
    print(f"[trace overhead gate OK: {(1 - ratio) * 100:+.1f}% "
          f"vs. trace=off]")

    if os.path.exists(LOOP_BASELINE):
        with open(LOOP_BASELINE) as f:
            baseline = json.load(f)
        row = next((r for r in baseline.get("rows", ())
                    if r["model"] == "loop-bound" and r["batch"] == 1),
                   {})
        recorded = row.get("request_steps_per_sec")
        if recorded and baseline.get("backend") == jax.default_backend():
            # the telemetry layer may slow NEITHER mode past the
            # recorded pre-telemetry baseline: trace=off because nobody
            # asked for anything, trace=on because the budget is ≤5%
            for label, val in (("trace=off", off), ("trace=on", on)):
                assert val >= (1.0 - MAX_OVERHEAD) * recorded, (
                    f"trace overhead gate: {label} {val:.1f} steps/s is "
                    f">{MAX_OVERHEAD * 100:.0f}% below the recorded "
                    f"whole-request baseline {recorded:.1f} "
                    f"(BENCH_decode_loop.json)")
                print(f"[{label}-vs-baseline gate OK: {val:.1f} vs. "
                      f"recorded {recorded:.1f} steps/s]")
    return rows


if __name__ == "__main__":
    run()
