"""Paper Figures 4/8: accuracy vs search width K — peaks at moderate K
(the winner's-curse analysis of Appendix E)."""
from benchmarks.common import evaluate_strategy, fmt, print_table

TASKS = ["sum", "sort"]
WIDTHS = [2, 4, 6, 8]


def run(n_eval: int = 0, tasks=None):
    all_rows = []
    for task in tasks or TASKS:
        rows = []
        for k in WIDTHS:
            r = evaluate_strategy(task, "fdm", n_eval=n_eval, k=k)
            r["strategy"] = f"fdm K={k}"
            rows.append(r)
            r2 = evaluate_strategy(task, "fdm_a", n_eval=n_eval, k1=k)
            r2["strategy"] = f"fdm_a K1={k}"
            rows.append(r2)
        print(f"\n== Fig 4/8 — width ablation (task: {task}) ==")
        print_table(fmt(rows), ["strategy", "accuracy", "tps"])
        all_rows += rows
    return all_rows


if __name__ == "__main__":
    run()
