"""Paper Table 2: FDM vs heuristic decoding; accuracy scales with width K
at the cost of TPS (inference-time scaling).
"""
from benchmarks.common import evaluate_strategy, fmt, print_table

TASKS = ["sum", "sort", "parity", "bracket"]
HEURISTICS = ["probability", "margin", "entropy"]
WIDTHS = [2, 3, 4]


def run(n_eval: int = 0, tasks=None):
    all_rows = []
    for task in tasks or TASKS:
        rows = [evaluate_strategy(task, s, n_eval=n_eval)
                for s in HEURISTICS]
        rows += [evaluate_strategy(task, "fdm", n_eval=n_eval, k=k)
                 for k in WIDTHS]
        for r, k in zip(rows[len(HEURISTICS):], WIDTHS):
            r["strategy"] = f"fdm (K={k})"
        print(f"\n== Table 2 — FDM vs heuristics (task: {task}) ==")
        print_table(fmt(rows), ["strategy", "accuracy", "tps",
                                "tokens_per_forward"])
        all_rows += rows
    return all_rows


if __name__ == "__main__":
    run()
