"""Paper Figures 6/7/10/11: FDM-A stage thresholds η₁ (qualified) and η₂
(borderline) — accuracy stays flat then drops as η₁ shrinks, TPS rises."""
from benchmarks.common import evaluate_strategy, fmt, print_table

TASK = "sort"
ETA1S = [1.0, 0.9, 0.8, 0.7, 0.6]
ETA2S = [0.75, 0.7, 0.65, 0.6, 0.55]


def run(n_eval: int = 0):
    rows = []
    for e1 in ETA1S:
        r = evaluate_strategy(TASK, "fdm_a", n_eval=n_eval,
                              eta1=e1, eta2=0.6)
        r["strategy"] = f"fdm_a η1={e1}"
        rows.append(r)
    print(f"\n== Fig 6 — η1 sweep (η2=0.6, task: {TASK}) ==")
    print_table(fmt(rows), ["strategy", "accuracy", "tps"])

    rows2 = []
    for e2 in ETA2S:
        r = evaluate_strategy(TASK, "fdm_a", n_eval=n_eval,
                              eta1=0.8, eta2=e2)
        r["strategy"] = f"fdm_a η2={e2}"
        rows2.append(r)
    print(f"\n== Fig 7 — η2 sweep (η1=0.8, task: {TASK}) ==")
    print_table(fmt(rows2), ["strategy", "accuracy", "tps"])
    return rows + rows2


if __name__ == "__main__":
    run()
