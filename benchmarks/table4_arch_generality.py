"""Architecture generality (paper Table 2's four model variants, here as
four architecture FAMILIES): the decode-order effect and FDM's gain are
architecture-agnostic — dense (LLaDA), MoE (mixtral/LLaDA-MoE analogue),
SSM (xLSTM) and hybrid (hymba) testbed models, same task, same strategies.
"""
from benchmarks.common import evaluate_strategy, fmt, print_table

TASK = "sort"
ARCHS = ["llada-8b", "mixtral-8x22b", "xlstm-125m", "hymba-1.5b"]


def run(n_eval: int = 0, archs=None, only_cached: bool = True):
    import os

    from benchmarks.common import CKPT_DIR, TASK_STEPS, bench_config
    rows = []
    for arch in archs or ARCHS:
        if only_cached and arch != "llada-8b":
            cfg = bench_config(arch)
            path = os.path.join(
                CKPT_DIR, f"{cfg.name}-{TASK}-{TASK_STEPS.get(TASK, 400)}.npz")
            if not os.path.exists(path):
                print(f"  [table4] skip {arch} (no cached testbed model — "
                      f"train with benchmarks.common.trained_model)")
                continue
        for strat in ["probability", "fdm", "fdm_a"]:
            r = evaluate_strategy(TASK, strat, n_eval=n_eval, arch=arch)
            r["arch"] = arch
            rows.append(r)
    print(f"\n== Table 4 (beyond paper) — architecture generality "
          f"(task: {TASK}) ==")
    print_table(fmt(rows), ["arch", "strategy", "accuracy", "tps",
                            "tokens_per_forward"])
    return rows


if __name__ == "__main__":
    run()
