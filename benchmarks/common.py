"""Shared benchmark harness: train one model per task once (cached), then
evaluate decoding strategies on held-out prompts.

The quality testbed is the band-2 gate from DESIGN.md: small masked-
diffusion LMs trained from scratch on bidirectionally-constrained synthetic
tasks; we reproduce the paper's *orderings* (FDM > heuristics, FDM-A ≈ FDM
accuracy at higher speed), not its absolute benchmark numbers — those need
the 8B public checkpoints this container cannot load.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DecodeConfig, TrainConfig, get_config
from repro.core import Decoder
from repro.data import CharTokenizer, TaskDataset
from repro.training import load, save, train

CKPT_DIR = os.environ.get("REPRO_BENCH_CKPTS", "/root/repo/.bench_ckpts")
ARCH = os.environ.get("REPRO_BENCH_ARCH", "llada-8b")
# per-task training budgets, calibrated so the decode-order effect is
# visible: hard tasks (carry chains, parities) train long enough to be
# competent; easy tasks stay deliberately light so confidence ordering
# still matters (a saturated model decodes correctly in ANY order).
TASK_STEPS = {"sum": 600, "parity": 1000, "bracket": 1000,
              "sort": 300, "reverse": 250}
TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_TRAIN_STEPS", "0"))
EVAL_N = int(os.environ.get("REPRO_BENCH_EVAL_N", "64"))
# decode-loop driver: fused (device-resident lax.while_loop, the default)
# vs the legacy host step loop; REPRO_HOST_LOOP=1 flips every suite to the
# host loop for A/B runs (benchmarks/loop_overhead.py measures both).
FUSED_LOOP = not bool(int(os.environ.get("REPRO_HOST_LOOP", "0")))

# evaluated model: the paper's own arch family at testbed scale
_MODEL_OVERRIDES = dict(num_layers=4, d_model=256, num_heads=4,
                        num_kv_heads=4, d_ff=1024)


def bench_config(arch: str = None):
    cfg = get_config(arch or ARCH).reduced(**_MODEL_OVERRIDES)
    return cfg


@functools.lru_cache(maxsize=None)
def trained_model(task: str, arch: Optional[str] = None,
                  steps: int = 0) -> Tuple:
    """Train (or load the cached) testbed model for ``task``."""
    cfg = bench_config(arch)
    tok = CharTokenizer(cfg.vocab_size)
    ds = TaskDataset(task, tok)
    steps = steps or TRAIN_STEPS or TASK_STEPS.get(task, 400)
    path = os.path.join(CKPT_DIR, f"{cfg.name}-{task}-{steps}.npz")
    tcfg = TrainConfig(batch_size=64, seq_len=ds.seq_len, steps=steps,
                       log_every=max(steps // 5, 1))
    if os.path.exists(path):
        from repro.models.model import init_model
        template = init_model(jax.random.PRNGKey(0), cfg)
        params, _, _ = load(path, template)
    else:
        print(f"  [train] {cfg.name} on '{task}' for {steps} steps …")
        params, _ = train(cfg, tcfg, ds.batches(tcfg.batch_size), log=None)
        save(path, params, step=steps)
    return params, cfg, ds, tok


def evaluate_strategy(task: str, strategy: str, n_eval: int = 0,
                      seed: int = 0, arch: Optional[str] = None,
                      batch_size: int = 0,
                      **dcfg_over) -> Dict[str, float]:
    """Accuracy (exact match) + TPS + tokens/forward for one strategy.

    ``batch_size`` (default 0 = all of ``n_eval`` in one batch) chops the
    eval set into smaller decode batches.  Forward-skipping strategies
    need this: a batched forward can only be skipped when EVERY row in
    the batch is skippable, so the per-request regime (serving latency,
    ``batch_size=1``) is where extrapolation's savings live — and a fair
    A/B runs the baseline at the same batch size.
    """
    params, cfg, ds, tok = trained_model(task, arch)
    n_eval = n_eval or EVAL_N
    batch = ds.eval_batch(n_eval)
    prompts = jnp.asarray(ds.prompts_only(batch))
    gen = ds.seq_len - prompts.shape[1]
    block = gen if gen <= 16 else max(gen // 2, 1)
    over = dict(gen_length=gen, block_size=block, steps=gen,
                strategy=strategy, fused_loop=FUSED_LOOP)
    over.update(dcfg_over)
    dcfg = DecodeConfig(**over)
    # params-mode Decoder: runners come from the weak cross-call cache
    # keyed on the (lru-cached) trained params, so every strategy suite
    # over the same task model shares compilations
    decoder = Decoder(params, cfg, dcfg)
    bs = batch_size or n_eval
    # warmup compile (excluded from timing) — both chunk shapes: the main
    # batch and any trailing partial chunk, so no trace lands in the loop
    decoder.generate(jax.random.PRNGKey(99), prompts[:bs])
    if n_eval % bs:
        decoder.generate(jax.random.PRNGKey(98), prompts[:n_eval % bs])
    outs, steps, fwd, skipped, revoked, wall = [], 0, 0.0, 0.0, 0.0, 0.0
    for i in range(0, n_eval, bs):
        out, stats = decoder.generate(jax.random.PRNGKey(seed + i),
                                      prompts[i:i + bs])
        outs.append(np.asarray(jax.device_get(out)))
        steps += stats.steps
        fwd += stats.forward_equivalents
        skipped += stats.skipped_forwards
        revoked += stats.revocations
        wall += stats.wall_time
    out_all = np.concatenate(outs, axis=0)
    em = ds.exact_match(out_all, batch)
    return {**{k: v for k, v in dcfg_over.items()},
            "task": task, "strategy": strategy, "accuracy": em,
            "tps": out_all.shape[0] * gen / max(wall, 1e-9),
            "steps": steps,
            "tokens_per_forward": out_all.shape[0] * gen / max(fwd, 1),
            "forward_equivalents": fwd,
            "skipped_forwards": skipped,
            "revocations": revoked}


def print_table(rows, cols) -> None:
    widths = [max(len(str(r.get(c, ""))) for r in rows + [{c: c}])
              for c in cols]
    line = "  ".join(c.ljust(w) for c, w in zip(cols, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(w)
                        for c, w in zip(cols, widths)))


def fmt(rows):
    out = []
    for r in rows:
        r = dict(r)
        r["accuracy"] = f"{r['accuracy']:.2%}"
        r["tps"] = f"{r['tps']:.1f}"
        if "tokens_per_forward" in r:
            r["tokens_per_forward"] = f"{r['tokens_per_forward']:.2f}"
        out.append(r)
    return out
