"""KV-cache policy A/B: decode cost under none vs prefix vs dual.

Speed section (always): one untrained testbed-size model, whole-request
driver, realistic geometry (prompt 128 / gen 128 in the full run — the
ISSUE acceptance point), best-of-N per-request wall time per policy.
The policies change *what* is computed per step — ``prefix`` forwards
only the ``gen_length`` window, ``dual`` only the active block, both
against the fixed-shape cache — so the wall-time ratio is the cache's
real saving, refresh forwards included.  ``forward_equivalents`` is
recorded alongside as the analytic cost (windowed steps pro-rated by
window/total, +1.0 per refresh) to separate model-compute savings from
dispatch noise.

Quality section (full runs only): exact-match on the trained sum
testbed per policy, via ``benchmarks.common.evaluate_strategy`` — the
policies are approximations (DESIGN.md "The KV cache") and the EM delta
is the price tag next to the speedup.

Emits ``BENCH_kv_cache.json`` at the repo root; ``benchmarks/run.py``
gates >10% regressions of the prefix/dual speedups against the recorded
baseline.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import _MODEL_OVERRIDES, print_table
from repro.configs import DecodeConfig, get_config
from repro.core import Decoder
from repro.models.model import init_model

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kv_cache.json")

POLICIES = ("none", "prefix", "dual")
REPEATS = 3


def _decode_seconds(params, cfg, dcfg, prompts,
                    repeats: int = REPEATS) -> Dict:
    """Best-of-N per-request wall seconds + exact forward-equivalents
    (untrained model: cost is identical regardless of output quality)."""
    decoder = Decoder(params, cfg, dcfg)
    decoder.generate(jax.random.PRNGKey(0), prompts)     # compile
    best, fwd = float("inf"), 0.0
    for r in range(repeats):
        _, stats = decoder.generate(jax.random.PRNGKey(r), prompts)
        best = min(best, stats.wall_time)
        fwd = stats.forward_equivalents
    return {"seconds": best, "forward_equivalents": fwd}


def run(fast: bool = False, n_eval: int = 0) -> List[Dict]:
    prompt_len, gen = (64, 64) if fast else (128, 128)
    block = 32
    cfg = get_config("llada-8b").reduced(**_MODEL_OVERRIDES)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = jnp.ones((1, prompt_len), jnp.int32)
    base = DecodeConfig(gen_length=gen, block_size=block, steps=gen,
                        strategy="probability")

    rows = []
    for policy in POLICIES:
        dcfg = dataclasses.replace(base, cache_policy=policy)
        m = _decode_seconds(params, cfg, dcfg, prompts)
        rows.append({"policy": policy, "prompt": prompt_len, "gen": gen,
                     "block": block,
                     "seconds": round(m["seconds"], 4),
                     "forward_equivalents":
                         round(m["forward_equivalents"], 2)})
    by = {r["policy"]: r for r in rows}
    for r in rows:
        r["speedup"] = round(by["none"]["seconds"]
                             / max(r["seconds"], 1e-9), 2)
    print("\n== KV-cache policy A/B: per-request decode time "
          "(whole-request driver) ==")
    print_table(rows, ["policy", "prompt", "gen", "block", "seconds",
                       "forward_equivalents", "speedup"])

    quality = []
    if not fast:
        from benchmarks.common import evaluate_strategy
        for policy in POLICIES:
            q = evaluate_strategy("sum", "probability",
                                  n_eval=n_eval or 32,
                                  cache_policy=policy)
            quality.append({"policy": policy, "task": "sum",
                            "accuracy": round(q["accuracy"], 4),
                            "tokens_per_forward":
                                round(q["tokens_per_forward"], 2)})
        print("\n== KV-cache policy quality (trained sum testbed) ==")
        print_table(quality, ["policy", "task", "accuracy",
                              "tokens_per_forward"])

    payload = {
        "benchmark": "kv_cache",
        "family": "llada-8b",
        "backend": jax.default_backend(),
        "prompt_len": prompt_len, "gen_length": gen, "block_size": block,
        "prefix_speedup": by["prefix"]["speedup"],
        "dual_speedup": by["dual"]["speedup"],
        "rows": rows,
        "quality": quality,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[wrote {OUT_PATH}; prefix {payload['prefix_speedup']}x, "
          f"dual {payload['dual_speedup']}x vs uncached]")
    return rows


if __name__ == "__main__":
    run()
