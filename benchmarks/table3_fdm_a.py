"""Paper Table 3: FDM-A vs acceleration baselines (halved-step heuristics,
EB, WINO) — the efficiency/performance trade-off.
"""
from benchmarks.common import evaluate_strategy, fmt, print_table

TASKS = ["sum", "sort"]


def run(n_eval: int = 0, tasks=None):
    all_rows = []
    for task in tasks or TASKS:
        rows = []
        for s in ["probability", "margin", "entropy"]:
            r = evaluate_strategy(task, s, n_eval=n_eval, steps=8)
            r["strategy"] = f"{s} (T/2)"
            rows.append(r)
        rows.append(evaluate_strategy(task, "eb", n_eval=n_eval))
        rows.append(evaluate_strategy(task, "wino", n_eval=n_eval))
        rows.append(evaluate_strategy(task, "fdm_a", n_eval=n_eval))
        print(f"\n== Table 3 — FDM-A vs dynamic baselines (task: {task}) ==")
        print_table(fmt(rows), ["strategy", "accuracy", "tps",
                                "tokens_per_forward"])
        all_rows += rows
    return all_rows


if __name__ == "__main__":
    run()
