"""Beyond paper — KV-cached serving quality parity.

The paper's related work (Fast-dLLM, dKV-cache) accelerates LLDM serving
by caching K/V; ``cache_policy="prefix"`` freezes the prompt's deep-layer
K/V while keeping the whole generation region live (masked-diffusion
models read future mask tokens as a length signal; see DESIGN.md "The KV
cache") and this table measures quality parity + the forward-cost
reduction against uncached decoding.  benchmarks/kv_cache.py has the
speed ablation across all three policies.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt, print_table, trained_model
from repro.configs import DecodeConfig
from repro.core import Decoder

TASK = "sort"


def run(n_eval: int = 32):
    params, cfg, ds, tok = trained_model(TASK)
    batch = ds.eval_batch(n_eval or 32)
    prompts = jnp.asarray(ds.prompts_only(batch))
    gen = ds.seq_len - prompts.shape[1]
    bs = gen // 2 if gen % 2 == 0 else gen
    rows = []
    for strat in ["probability", "fdm", "fdm_a"]:
        dcfg = DecodeConfig(gen_length=gen, block_size=bs, steps=gen,
                            strategy=strat)
        o1, s1 = Decoder(params, cfg, dcfg).generate(
            jax.random.PRNGKey(0), prompts)
        o2, s2 = Decoder(params, cfg,
                         dataclasses.replace(dcfg, cache_policy="prefix")
                         ).generate(jax.random.PRNGKey(0), prompts)
        agree = float(jnp.mean((o1 == o2).astype(jnp.float32)))
        rows.append({
            "strategy": strat,
            "accuracy": ds.exact_match(np.asarray(o1), batch),
            "acc_cached": f"{ds.exact_match(np.asarray(o2), batch):.2%}",
            "token_agree": f"{agree:.2%}",
            "fwd_full": f"{s1.forward_equivalents:.1f}",
            "fwd_cached": f"{s2.forward_equivalents:.1f}",
            "tps": s1.tps,
        })
    print("\n== Table 5 (beyond paper) — prefix-cached serving "
          f"(task: {TASK}) ==")
    print_table(fmt(rows), ["strategy", "accuracy", "acc_cached",
                            "token_agree", "fwd_full", "fwd_cached"])
    print("(fwd counts are full-sequence-forward equivalents; the cached"
          " path's advantage grows with prompt length — here prompts are"
          " short, production prompts dominate)")
    return rows


if __name__ == "__main__":
    run()
