"""Carry-ful strategy ablation: what does cross-step decode state buy?

Two questions, both on the trained sum testbed (the task where decode
order and forward count are most visible):

* **Confidence extrapolation** (``extrapolate``, core/extrapolate.py) —
  how many model forwards does trajectory extrapolation skip, and what
  does the early commitment cost in exact match?  The baseline is
  vanilla confidence decoding (``probability``): with skipping disabled
  the two are bit-identical (tested), so the delta is PURE extrapolation
  effect.  Swept over ``extrap_tau`` — lower thresholds skip more and
  trust the carried candidates earlier.
* **WINO revocation** (``wino_r``, core/wino.py) — the carry-ful variant
  verifies pending commits on the NEXT step's regular forward (1
  forward/step) where the stateless ``wino`` baseline re-forwards inside
  every step (2 forwards/step): same commit-then-revoke idea, half the
  forward bill, plus a budgeted un-commit that the stats surface as
  ``SampleStats.revocations``.

Emits ``BENCH_ablation_carry.json`` with the headline
``extrap_fwd_reduction`` (fraction of the vanilla baseline's forwards
that the default-τ extrapolation row avoided) so later PRs can regress
against a recorded number.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import evaluate_strategy, fmt, print_table

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_ablation_carry.json")

TASK = "sum"
TAUS = (0.85, 0.92, 0.97)
DEFAULT_TAU = 0.92         # DecodeConfig.extrap_tau — the headline row


def run(n_eval: int = 0, taus=None) -> List[Dict]:
    taus = taus or TAUS
    # batch_size=1 throughout: a batched forward can only be skipped when
    # EVERY batch row is skippable, so the per-request regime (serving
    # latency) is where extrapolation's savings live — and the baseline
    # must decode at the same batch size for the comparison to be fair
    def ev(strategy, **kw):
        return evaluate_strategy(TASK, strategy, n_eval=n_eval,
                                 batch_size=1, **kw)

    rows = [ev("probability")]
    base_fwd = rows[0]["forward_equivalents"]
    for tau in taus:
        rows.append(ev("extrapolate", extrap_tau=tau))
    rows.append(ev("wino"))
    rows.append(ev("wino_r"))
    for r in rows:
        r["fwd_reduction"] = round(
            1.0 - r["forward_equivalents"] / max(base_fwd, 1e-9), 3)

    print("\n== carry-ful strategy ablation (sum testbed) ==")
    print_table(fmt(rows), ["strategy", "extrap_tau", "accuracy",
                            "forward_equivalents", "skipped_forwards",
                            "revocations", "fwd_reduction", "tps"])

    headline = next((r for r in rows if r.get("extrap_tau") == DEFAULT_TAU),
                    rows[1])           # first extrapolate row as fallback
    head_tau = headline["extrap_tau"]  # may differ from DEFAULT_TAU when
    payload = {                        # the caller swept other taus
        "benchmark": "ablation_carry",
        "task": TASK,
        "extrap_tau": head_tau,
        "extrap_fwd_reduction": headline["fwd_reduction"],
        "extrap_accuracy": headline["accuracy"],
        "baseline_accuracy": rows[0]["accuracy"],
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[wrote {OUT_PATH}; extrapolate τ={head_tau} skipped "
          f"{headline['skipped_forwards']:.0f} forwards = "
          f"{headline['fwd_reduction']:.0%} of the vanilla bill at "
          f"{headline['accuracy']:.0%} EM vs {rows[0]['accuracy']:.0%}]")
    return rows


if __name__ == "__main__":
    run()
