"""Benchmark entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]``

Env knobs: REPRO_BENCH_TRAIN_STEPS (default 1200), REPRO_BENCH_EVAL_N (64),
REPRO_BENCH_ARCH (llada-8b).
"""
import argparse
import json
import os
import time


def _loop_with_regression_gate(batches=None):
    """Run the decode-loop benchmark and assert steps/sec has not
    regressed >10% vs. the recorded ``BENCH_decode_loop.json`` baseline
    (loop-bound batch-1) — for BOTH fused drivers: the per-block loop and
    the whole-request single-dispatch driver.  A whole-request column
    missing from an old baseline is gated against the per-block number
    instead (the new driver must never be slower than what it replaced).

    ``loop_overhead.run`` rewrites the baseline file unconditionally, so
    the old contents are snapshotted first and RESTORED whenever the new
    numbers must not become the baseline: on a failed gate, on partial
    ``--fast`` runs (which would destroy the full batch sweep future PRs
    regress against), and on ANY slower-than-baseline gated number — a
    regression may not ratchet the baseline down, even a sub-10% one
    (otherwise repeated 9% slips would compound unnoticed).  Recording a
    deliberately slower baseline therefore requires running
    ``benchmarks.loop_overhead`` directly.

    ``REPRO_BENCH_SMOKE_OUT=<path>``: also write THIS run's fresh
    measurement there, surviving any baseline restore — how the CI
    bench-smoke job exports its artifact without ratcheting the recorded
    baseline from a noisy shared runner."""
    from benchmarks import loop_overhead

    baseline = raw_baseline = None
    if os.path.exists(loop_overhead.OUT_PATH):
        with open(loop_overhead.OUT_PATH) as f:
            raw_baseline = f.read()
        baseline = json.loads(raw_baseline)
    partial = batches is not None

    def restore():
        if raw_baseline is not None:
            with open(loop_overhead.OUT_PATH, "w") as f:
                f.write(raw_baseline)

    try:
        rows = loop_overhead.run(batches=batches)
    except BaseException:
        restore()                      # an aborted run is no baseline
        raise
    smoke_out = os.environ.get("REPRO_BENCH_SMOKE_OUT")
    if smoke_out:
        with open(loop_overhead.OUT_PATH) as f:
            fresh = f.read()
        with open(smoke_out, "w") as f:
            f.write(fresh)
        print(f"[smoke copy of this run's numbers -> {smoke_out}]")
    if baseline and baseline.get("backend") == \
            __import__("jax").default_backend():
        old_row = next((r for r in baseline["rows"]
                        if r["model"] == "loop-bound" and r["batch"] == 1),
                       None) or {}
        new_row = next(r for r in rows
                       if r["model"] == "loop-bound" and r["batch"] == 1)
        gates = [("per-block fused", "fused_steps_per_sec",
                  old_row.get("fused_steps_per_sec")),
                 ("whole-request", "request_steps_per_sec",
                  old_row.get("request_steps_per_sec")
                  or old_row.get("fused_steps_per_sec"))]
        slower = False
        for label, col, old in gates:
            new = new_row.get(col)
            if not (old and new):
                continue
            if new < 0.9 * old:
                restore()
                raise AssertionError(
                    f"decode-loop regression: {label} loop-bound batch-1 "
                    f"{new:.1f} steps/s vs. recorded baseline {old:.1f} "
                    f"(>10% slower) — baseline file left unchanged; "
                    f"investigate before re-recording "
                    f"BENCH_decode_loop.json")
            slower = slower or new < old
            print(f"[loop regression gate OK ({label}): {new:.1f} vs. "
                  f"baseline {old:.1f} steps/s]")
        if slower and not partial:
            restore()
            print("[slower than baseline (within tolerance): baseline "
                  "file kept — re-record via benchmarks.loop_overhead "
                  "if intentional]")
    if partial:
        restore()
        print("[--fast loop run: full-sweep baseline file restored]")
    return rows


def _kv_cache_with_regression_gate(fast: bool = False):
    """Run the KV-cache policy A/B and assert the prefix/dual speedups
    have not regressed >10% vs. the recorded ``BENCH_kv_cache.json``
    baseline.  Same baseline-stewardship rules as the loop gate:
    ``kv_cache.run`` rewrites the file unconditionally, so the old
    contents are snapshotted and restored on a failed gate, on partial
    ``--fast`` runs (smaller geometry, no quality section — not a valid
    full baseline), and on any slower-than-baseline gated number.
    Re-recording a deliberately slower baseline means running
    ``benchmarks.kv_cache`` directly."""
    from benchmarks import kv_cache

    baseline = raw_baseline = None
    if os.path.exists(kv_cache.OUT_PATH):
        with open(kv_cache.OUT_PATH) as f:
            raw_baseline = f.read()
        baseline = json.loads(raw_baseline)

    def restore():
        if raw_baseline is not None:
            with open(kv_cache.OUT_PATH, "w") as f:
                f.write(raw_baseline)

    try:
        rows = kv_cache.run(fast=fast)
    except BaseException:
        restore()
        raise
    by = {r["policy"]: r for r in rows}
    # the speedup is geometry-dependent (the window/total ratio IS the
    # saving), so only gate like-for-like: same backend AND the same
    # prompt/gen point as the recorded baseline — a --fast run against a
    # full-geometry baseline would flag a phantom regression
    if baseline and baseline.get("backend") == \
            __import__("jax").default_backend() and \
            baseline.get("gen_length") == rows[0]["gen"] and \
            baseline.get("prompt_len") == rows[0]["prompt"]:
        slower = False
        for key, col in (("prefix", "prefix_speedup"),
                         ("dual", "dual_speedup")):
            old, new = baseline.get(col), by[key]["speedup"]
            if not (old and new):
                continue
            if new < 0.9 * old:
                restore()
                raise AssertionError(
                    f"kv-cache regression: {key} speedup {new}x vs. "
                    f"recorded baseline {old}x (>10% slower) — baseline "
                    f"file left unchanged; investigate before "
                    f"re-recording BENCH_kv_cache.json")
            slower = slower or new < old
            print(f"[kv-cache regression gate OK ({key}): {new}x vs. "
                  f"baseline {old}x]")
        if slower and not fast:
            restore()
            print("[slower than baseline (within tolerance): baseline "
                  "file kept — re-record via benchmarks.kv_cache if "
                  "intentional]")
    if fast:
        restore()
        print("[--fast kv-cache run: full-geometry baseline file "
              "restored]")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small eval sets (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table1,fig2")
    args = ap.parse_args()

    from benchmarks import (ablation_carry, ablation_eta, ablation_gamma,
                            ablation_k, fig2_consistency,
                            kernel_confidence, loop_overhead,
                            serving_load, table1_decode_order,
                            table2_fdm_scaling, table3_fdm_a,
                            table4_arch_generality,
                            table5_cached_serving, trace_overhead)
    n_eval = 16 if args.fast else 0
    suites = {
        "table1": lambda: table1_decode_order.run(n_eval=n_eval),
        "table2": lambda: table2_fdm_scaling.run(
            n_eval=n_eval, tasks=["sum", "sort"] if args.fast else None),
        "table3": lambda: table3_fdm_a.run(
            n_eval=n_eval, tasks=["sum"] if args.fast else None),
        "fig2": lambda: fig2_consistency.run(
            n_examples=8 if args.fast else 16),
        "ablation_k": lambda: ablation_k.run(
            n_eval=n_eval, tasks=["sort"] if args.fast else None),
        "ablation_gamma": lambda: ablation_gamma.run(
            n_eval=n_eval, tasks=["sort"] if args.fast else None),
        "ablation_eta": lambda: ablation_eta.run(n_eval=n_eval),
        "ablation_carry": lambda: ablation_carry.run(
            n_eval=n_eval, taus=(0.92,) if args.fast else None),
        "table4": lambda: table4_arch_generality.run(
            n_eval=n_eval,
            archs=["llada-8b", "xlstm-125m"] if args.fast else None),
        "table5": lambda: table5_cached_serving.run(
            n_eval=16 if args.fast else 32),
        "serving": lambda: (
            serving_load.run(
                n_requests=16 if args.fast else 64,
                concurrency=4 if args.fast else 8),
            serving_load.run_degraded(
                n_requests=24 if args.fast else 64)),
        "kernel": kernel_confidence.run,
        "loop": lambda: _loop_with_regression_gate(
            batches=(1, 4) if args.fast else None),
        "trace": lambda: trace_overhead.run(fast=args.fast),
        "kv_cache": lambda: _kv_cache_with_regression_gate(
            fast=args.fast),
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    t0 = time.perf_counter()
    for name in chosen:
        t = time.perf_counter()
        suites[name]()
        print(f"[{name} done in {time.perf_counter() - t:.0f}s]")
    print(f"\nall benchmarks done in {time.perf_counter() - t0:.0f}s")


if __name__ == "__main__":
    main()
