"""Kernel benchmark: fused confidence scoring vs the naive reference.

On CPU we time the *naive* jnp path and report the fused kernel's derived
HBM-traffic advantage (the kernel itself runs in interpret mode here — its
wall time is Python emulation, not TPU time).  The roofline argument: the
reduction is strictly memory-bound, so the expected TPU speedup equals the
traffic ratio.
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels.confidence import confidence_fused
from repro.kernels.ref import confidence_ref


def traffic_model(rows: int, vocab: int, dtype_bytes: int = 2):
    """HBM bytes: fused = one read of logits; naive = softmax read+write,
    top-k read, entropy read (XLA typically fuses some — we count the
    conservative 3-pass version measured from HLO on this shape)."""
    logits = rows * vocab * dtype_bytes
    fused = logits
    naive = 3 * logits + rows * vocab * 4   # + f32 softmax materialization
    return fused, naive


def run(rows: int = 256, vocab: int = 50304, iters: int = 5):
    print("\n== kernel: fused confidence scoring ==")
    logits = jax.random.normal(jax.random.PRNGKey(0),
                               (rows, vocab), jnp.bfloat16)
    ref_jit = jax.jit(confidence_ref)
    out = ref_jit(logits)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = ref_jit(logits)
    jax.block_until_ready(out)
    t_naive = (time.perf_counter() - t0) / iters

    # correctness of the fused kernel on this exact shape (interpret mode)
    small = logits[:16]
    fused_out = confidence_fused(small)
    ref_out = confidence_ref(small)
    ok = bool(jnp.all(fused_out[0] == ref_out[0]))

    fused_b, naive_b = traffic_model(rows, vocab)
    print(f"shape ({rows}, {vocab})  naive jnp wall (CPU): "
          f"{t_naive * 1e3:.2f} ms/call")
    print(f"HBM traffic: naive {naive_b / 2**20:.1f} MiB vs fused "
          f"{fused_b / 2**20:.1f} MiB  -> {naive_b / fused_b:.1f}x less; "
          f"memory-bound => ~{naive_b / fused_b:.1f}x TPU speedup expected")
    print(f"fused-vs-ref argmax agreement on subsample: {ok}")
    return {"t_naive_ms": t_naive * 1e3,
            "traffic_ratio": naive_b / fused_b, "agree": ok}


if __name__ == "__main__":
    run()
