"""Paper Table 1: decoding order matters.

Random vs Margin vs FDM-A on one benchmark — accuracy should rise from
Random -> Margin -> FDM-A while FDM-A is also the fastest (fewer steps).
"""
from benchmarks.common import evaluate_strategy, fmt, print_table

TASK = "sort"


def run(n_eval: int = 0):
    rows = [evaluate_strategy(TASK, s, n_eval=n_eval)
            for s in ["random", "margin", "fdm_a"]]
    print(f"\n== Table 1 — decode order matters (task: {TASK}) ==")
    print_table(fmt(rows), ["strategy", "accuracy", "tps",
                            "tokens_per_forward"])
    return rows


if __name__ == "__main__":
    run()
