"""Closed-loop load generation against the async serving front end.

Boots the full stack in-process — trained testbed model → ServingEngine
→ AsyncScheduler → stdlib HTTP/SSE server on an ephemeral port — then
drives it with ``concurrency`` closed-loop clients (each submits, blocks
for the result, immediately submits again) over real sockets until
``n_requests`` complete.  A sampler thread polls ``/healthz`` for queue
depth throughout.  This measures what a single-process deployment of
this stack actually delivers under sustained traffic: end-to-end
latency quantiles (queueing + batching + decode + HTTP), aggregate
token throughput, and how deep the admission queue runs at the chosen
concurrency.

Emits ``BENCH_serving.json`` at the repo root (via ``benchmarks.run
--only serving``) so later serving PRs have a baseline to compare
against.  Latency here includes real queueing: closed-loop clients at
``concurrency`` > ``max_batch`` deliberately oversubscribe the engine,
so p95 ≫ p50 is expected and the interesting regressions are in
``decode_tps`` (decode efficiency) and ``throughput_tps`` (end-to-end).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from benchmarks.common import print_table, trained_model
from repro.configs import (DecodeConfig, DegradeConfig, RouterConfig,
                           ServerConfig, default_block_size, get_config)
from repro.models.model import init_model
from repro.serving import (ModelRouter, ServerError, ServerThread,
                           ServingClient, ServingEngine)

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")

TASK = "sum"
STRATEGIES = ("fdm_a", "probability")     # mixed-strategy traffic
_PROMPT = [3, 5, 2, 7, 4, 1]              # token ids for run_degraded


def run(n_requests: int = 64, concurrency: int = 8,
        max_batch: int = 8, strategy_mix: Optional[tuple] = None
        ) -> List[Dict]:
    params, cfg, ds, tok = trained_model(TASK)
    gen = ds.seq_len - (1 + ds.prompt_len)
    dcfg = DecodeConfig(gen_length=gen,
                        block_size=default_block_size(gen),
                        steps=gen, strategy="fdm_a")
    router = ModelRouter(RouterConfig())
    router.register("bench", lambda: ServingEngine(
        params, cfg, dcfg, max_batch=max_batch))
    handle = ServerThread(router, ServerConfig(port=0),
                          tokenizer=tok).start()
    mix = strategy_mix or STRATEGIES
    try:
        rows = _drive(handle, ds, n_requests, concurrency, mix)
    finally:
        handle.stop()
    payload = {"task": TASK, "gen_length": gen,
               "max_batch": max_batch, "strategies": list(mix),
               "rows": rows}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    head = rows[0]
    print(f"[wrote {OUT_PATH}; {head['requests']} reqs @ "
          f"c={head['concurrency']}: p50 {head['p50_latency_s']:.3f}s "
          f"p95 {head['p95_latency_s']:.3f}s, "
          f"{head['throughput_tps']:.1f} tok/s end-to-end, "
          f"decode {head['decode_tps']:.1f} tok/s, "
          f"max queue depth {head['max_queue_depth']}]")
    return rows


def _drive(handle, ds, n_requests: int, concurrency: int,
           mix) -> List[Dict]:
    client = ServingClient(handle.host, handle.port, timeout=600.0)
    prompts = ds.prompts_only(ds.eval_batch(max(n_requests, 1)))
    latencies: List[float] = []
    errors: List[str] = []
    counter = {"next": 0}
    lock = threading.Lock()
    depth_samples: List[int] = []
    stop_sampling = threading.Event()

    def sampler():
        while not stop_sampling.is_set():
            try:
                depth = client.healthz()["queue_depth"].get("bench", 0)
                depth_samples.append(depth)
            except Exception:
                pass
            stop_sampling.wait(0.05)

    def worker(wid: int):
        while True:
            with lock:
                i = counter["next"]
                if i >= n_requests:
                    return
                counter["next"] = i + 1
            prompt = prompts[i % len(prompts)].tolist()
            strategy = mix[i % len(mix)]
            t0 = time.perf_counter()
            try:
                res = client.generate(prompt, strategy=strategy,
                                      wait=True)
                assert res["status"] == "ok"
            except Exception as e:
                with lock:
                    errors.append(f"req {i}: {e}")
                return
            with lock:
                latencies.append(time.perf_counter() - t0)

    sam = threading.Thread(target=sampler, daemon=True)
    sam.start()
    t_start = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    span = time.perf_counter() - t_start
    stop_sampling.set()
    sam.join(timeout=2)
    if errors:
        raise RuntimeError(f"{len(errors)} load-gen failures; first: "
                           f"{errors[0]}")
    metrics = _parse_metrics(client.metrics_text())
    gen = ds.seq_len - (1 + ds.prompt_len)
    row = {"requests": len(latencies),
           "concurrency": concurrency,
           "span_s": round(span, 3),
           "p50_latency_s": round(float(np.percentile(latencies, 50)), 4),
           "p95_latency_s": round(float(np.percentile(latencies, 95)), 4),
           "mean_latency_s": round(float(np.mean(latencies)), 4),
           "throughput_rps": round(len(latencies) / span, 2),
           "throughput_tps": round(len(latencies) * gen / span, 1),
           "decode_tps": round(metrics.get("repro_decode_tps", 0.0), 1),
           "batches": int(metrics.get("repro_requests_batches_total", 0)),
           "max_queue_depth": int(max(depth_samples, default=0)),
           "mean_queue_depth": round(float(np.mean(depth_samples))
                                     if depth_samples else 0.0, 2)}
    print_table([row], ["requests", "concurrency", "p50_latency_s",
                        "p95_latency_s", "throughput_tps", "decode_tps",
                        "batches", "max_queue_depth",
                        "mean_queue_depth"])
    return [row]


def run_degraded(n_requests: int = 64, max_queue_depth: int = 8,
                 pause_s: float = 0.03, gen_length: int = 32
                 ) -> Dict[str, Dict]:
    """The degradation-ladder A/B: the same open-loop overload burst
    (submissions paced faster than the engine drains) against a server
    with the ladder OFF, then ON.  With the ladder on, admissions past
    the rung thresholds decode with scaled-down step budgets, the queue
    drains faster, and fewer requests hit the 429 cliff — shed steps
    before shedding requests.  Recorded under the ``degraded`` key of
    BENCH_serving.json; the acceptance bar is
    ``ladder_on.rejected_429 < ladder_off.rejected_429``.

    Testbed: the reduced untrained model at a steps-dominated decode
    length (the trained 4-token testbed is fixed-overhead-bound, so
    halving its step budget moves nothing), ``max_batch=1`` so the A/B
    isolates the ladder's capacity effect from batch-shape
    fragmentation (mixed step budgets land in different batch buckets),
    and the offered rate pinned between the full-quality and cheapened
    service rates — the regime the ladder exists for.
    """
    cfg = get_config("llada-8b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    dcfg = DecodeConfig(gen_length=gen_length,
                        block_size=default_block_size(gen_length),
                        steps=gen_length, strategy="probability")
    results: Dict[str, Dict] = {}
    for mode in ("ladder_off", "ladder_on"):
        router = ModelRouter(RouterConfig())
        router.register("bench", lambda: ServingEngine(
            params, cfg, dcfg, max_batch=1))
        scfg = ServerConfig(
            port=0, max_queue_depth=max_queue_depth,
            degrade=DegradeConfig(enabled=(mode == "ladder_on")))
        handle = ServerThread(router, scfg).start()
        try:
            # single-shot client: this run COUNTS 429s
            client = ServingClient(handle.host, handle.port,
                                   timeout=600.0, max_retries=0)
            # warm every step budget the burst can decode at — the full
            # budget plus each rung's cheapened budget — so the A/B
            # measures the ladder, not one-off JIT compiles of the
            # scaled-down step counts mid-burst
            num_blocks = gen_length // dcfg.block_size
            budgets = {dcfg.steps} | {
                max(num_blocks, int(dcfg.steps * r.steps_scale))
                for r in scfg.degrade.rungs}
            for steps in sorted(budgets, reverse=True):
                client.generate(_PROMPT, steps=steps, wait=True)
            accepted = rejected = 0
            t0 = time.perf_counter()
            for i in range(n_requests):
                try:
                    client.generate(_PROMPT, wait=False)
                    accepted += 1
                except ServerError as e:
                    if e.status != 429:
                        raise
                    rejected += 1
                time.sleep(pause_s)
            # drain the backlog before scraping the final counters
            while True:
                m = _parse_metrics(client.metrics_text())
                if not m.get("repro_queue_depth") and \
                        not m.get("repro_decoding"):
                    break
                time.sleep(0.05)
            span = time.perf_counter() - t0
            results[mode] = {
                "offered": n_requests,
                "accepted": accepted,
                "rejected_429": rejected,
                "degraded_admissions":
                    int(m.get("repro_requests_degraded_total", 0)),
                "finished":
                    int(m.get("repro_requests_finished_total", 0)),
                "span_s": round(span, 3)}
        finally:
            handle.stop()
    off, on = results["ladder_off"], results["ladder_on"]
    print(f"[degraded-mode A/B @ depth cap {max_queue_depth}, "
          f"pause {pause_s * 1e3:.0f}ms: ladder off "
          f"{off['rejected_429']}/{off['offered']} rejected; ladder on "
          f"{on['rejected_429']}/{on['offered']} rejected, "
          f"{on['degraded_admissions']} admissions cheapened]")
    payload = {}
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            payload = json.load(f)
    payload["degraded"] = {"max_queue_depth": max_queue_depth,
                           "pause_s": pause_s,
                           "gen_length": gen_length, **results}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return results


def _parse_metrics(text: str) -> Dict[str, float]:
    """Flatten the Prometheus exposition (labels dropped — one model)."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        if line.startswith("#") or " " not in line:
            continue
        name, val = line.rsplit(" ", 1)
        name = name.split("{", 1)[0]
        try:
            out[name] = float(val)
        except ValueError:
            pass
    return out


if __name__ == "__main__":
    run()
