"""Paper Figures 5/9: the pruning threshold γ trade-off."""
from benchmarks.common import evaluate_strategy, fmt, print_table

TASKS = ["sum", "sort"]
GAMMAS = [0.1, 0.3, 0.5, 0.7, 0.9]


def run(n_eval: int = 0, tasks=None):
    all_rows = []
    for task in tasks or TASKS:
        rows = []
        for g in GAMMAS:
            for k in [2, 4]:
                r = evaluate_strategy(task, "fdm", n_eval=n_eval,
                                      gamma=g, k=k)
                r["strategy"] = f"fdm γ={g} K={k}"
                rows.append(r)
        print(f"\n== Fig 5/9 — γ ablation (task: {task}) ==")
        print_table(fmt(rows), ["strategy", "accuracy", "tps"])
        all_rows += rows
    return all_rows


if __name__ == "__main__":
    run()
