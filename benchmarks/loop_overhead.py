"""Decode-loop overhead: host step loop vs per-block fused vs whole-request.

Measures steps/sec of the SAME strategy under the three drivers
(``DecodeConfig.fused_loop`` / ``fused_blocks``) across batch sizes.  The
decode math is identical (parity-tested in tests/test_loop.py), so any gap
is pure loop overhead:

* host → per-block fused removes the per-STEP costs: the jitted dispatch,
  the host RNG split, ~30 un-jitted jnp ops in the strategy body, and the
  blocking ``bool(device_get(any(active)))`` termination sync;
* per-block fused → whole-request removes the per-BLOCK costs: one
  dispatch + carry handover per block, leaving a single compiled dispatch
  per request (the O(1)-dispatch regime §5.3's acceleration phase wants).

Two model points, same llada-8b family:

* ``loop-bound`` (2 layers, d=128) — the dispatch-bound regime the fused
  driver targets; on CPU the per-step forward (~1 ms) is comparable to the
  host-loop overhead, so the ratio isolates the loop machinery.  Its
  batch-1 speedup is the ISSUE-1 acceptance number (``batch1_speedup``).
* ``testbed`` (4 layers, d=256) — the quality-benchmark model, recorded as
  context: on CPU its ~15 ms forward hides the overhead (ratio ≈ 1); on
  accelerators the forward shrinks and every model drifts toward the
  loop-bound point — that is exactly the regime §5.3 cares about.

Emits ``BENCH_decode_loop.json`` at the repo root (via ``benchmarks.run``)
so later PRs have a perf baseline to regress against.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import _MODEL_OVERRIDES, print_table
from repro.configs import DecodeConfig, get_config
from repro.core import Decoder
from repro.models.model import init_model

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_decode_loop.json")

GEN, BLOCK = 64, 32
PROMPT_LEN = 8
BATCHES = (1, 2, 4, 8)
REPEATS = 5
MODELS = {
    # the dispatch-bound point: loop overhead ~ per-step compute
    "loop-bound": dict(num_layers=2, d_model=128, num_heads=4,
                       num_kv_heads=4, d_ff=256),
    # the quality-testbed model (benchmarks/common.py), for context
    "testbed": _MODEL_OVERRIDES,
}


def _steps_per_sec(params, prompts, cfg, dcfg,
                   repeats: int = REPEATS) -> Dict:
    """Best-of-N steps/sec (the model is untrained — decode quality is
    irrelevant here and the step count is identical either way)."""
    decoder = Decoder(params, cfg, dcfg)
    decoder.generate(jax.random.PRNGKey(0), prompts)     # compile
    best, steps = 0.0, 0
    for r in range(repeats):
        _, stats = decoder.generate(jax.random.PRNGKey(r), prompts)
        best = max(best, stats.steps / max(stats.wall_time, 1e-9))
        steps = stats.steps
    return {"steps_per_sec": best, "steps": steps}


def run(strategy: str = "probability", batches=None) -> List[Dict]:
    batches = batches or BATCHES
    rows = []
    for model_key, overrides in MODELS.items():
        cfg = get_config("llada-8b").reduced(**overrides)
        params = init_model(jax.random.PRNGKey(0), cfg)
        base = DecodeConfig(gen_length=GEN, block_size=BLOCK, steps=GEN,
                            strategy=strategy)
        for b in batches:
            prompts = jnp.ones((b, PROMPT_LEN), jnp.int32)
            host = _steps_per_sec(params, prompts, cfg,
                                  dataclasses.replace(base,
                                                      fused_loop=False))
            block = _steps_per_sec(params, prompts, cfg,
                                   dataclasses.replace(
                                       base, fused_loop=True,
                                       fused_blocks=False))
            request = _steps_per_sec(params, prompts, cfg,
                                     dataclasses.replace(
                                         base, fused_loop=True,
                                         fused_blocks=True))
            rows.append({
                "model": model_key, "batch": b, "strategy": strategy,
                "steps": request["steps"],
                "host_steps_per_sec": round(host["steps_per_sec"], 1),
                "fused_steps_per_sec": round(block["steps_per_sec"], 1),
                "request_steps_per_sec": round(request["steps_per_sec"], 1),
                "speedup": round(block["steps_per_sec"]
                                 / max(host["steps_per_sec"], 1e-9), 2),
                "request_speedup": round(request["steps_per_sec"]
                                         / max(host["steps_per_sec"],
                                               1e-9), 2),
            })
    print("\n== decode-loop overhead: host loop vs per-block fused vs "
          "whole-request ==")
    print_table(rows, ["model", "batch", "strategy", "steps",
                       "host_steps_per_sec", "fused_steps_per_sec",
                       "request_steps_per_sec", "speedup",
                       "request_speedup"])
    batch1 = next(r for r in rows
                  if r["model"] == "loop-bound" and r["batch"] == 1)
    payload = {
        "benchmark": "decode_loop",
        "family": "llada-8b",
        "backend": jax.default_backend(),
        "gen_length": GEN, "block_size": BLOCK,
        "batch1_speedup": batch1["speedup"],
        "batch1_request_speedup": batch1["request_speedup"],
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[wrote {OUT_PATH}; loop-bound batch-1: per-block fused/host = "
          f"{payload['batch1_speedup']}x, whole-request/host = "
          f"{payload['batch1_request_speedup']}x]")
    return rows


if __name__ == "__main__":
    run()
