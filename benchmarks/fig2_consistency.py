"""Paper Figure 2: consistency ratio between local-only selection and
local+global (FDM) selection, as a function of decoding progress.

Both strategies pick a token from the SAME x_{t-1} at each step; we record
whether they chose the same position/token.  The paper observes ~50 %
agreement early (context-poor) rising above 90 % late — the observation
that motivates FDM-A's phase schedule.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_model
from repro.core import fully_masked, score_logits
from repro.core.fdm import fdm_select
from repro.core.strategies import NEG
from repro.models.model import forward

TASK = "sort"


def run(n_examples: int = 16, k: int = 2, gamma: float = 0.6):
    params, cfg, ds, tok = trained_model(TASK)

    @jax.jit
    def model_fn(x):
        return forward(params, x, cfg)[0]

    batch = ds.eval_batch(n_examples)
    prompts = jnp.asarray(ds.prompts_only(batch))
    gen = ds.seq_len - prompts.shape[1]
    x = fully_masked(cfg, prompts, gen)

    agreement = []
    for step in range(gen):
        active = x == cfg.mask_token_id
        logits = model_fn(x)
        s = score_logits(logits)
        conf = jnp.where(active, s.max_prob, NEG)
        local_pos = jnp.argmax(conf, axis=-1)                  # (B,)
        x_fdm, _ = fdm_select(x, logits, active, model_fn, cfg,
                              k=k, gamma=gamma, n=1)
        fdm_pos = jnp.argmax(
            (x_fdm != x).astype(jnp.int32), axis=-1)
        agree = float(jnp.mean((local_pos == fdm_pos).astype(jnp.float32)))
        agreement.append(agree)
        x = x_fdm   # follow the FDM trajectory (the paper's protocol)

    print(f"\n== Figure 2 — local vs local+global consistency "
          f"(task: {TASK}, K={k}) ==")
    print("step  fraction_of_decode  agreement")
    for i, a in enumerate(agreement):
        bar = "#" * int(a * 40)
        print(f"{i:4d}  {i / max(len(agreement) - 1, 1):18.2f}  "
              f"{a:.2f} {bar}")
    early = float(np.mean(agreement[: max(gen // 4, 1)]))
    late = float(np.mean(agreement[-max(gen // 4, 1):]))
    print(f"early-phase agreement {early:.2f}  late-phase {late:.2f}"
          f"  (paper: ~0.5 -> >0.9)")
    return agreement


if __name__ == "__main__":
    run()
