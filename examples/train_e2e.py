"""End-to-end training driver: a ~100M-parameter LLDA-family model trained
for a few hundred steps on the synthetic suite, with eval-time generation
accuracy tracked across checkpoints.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--small]

(--small switches to a few-M-param model so the example finishes in
minutes on a laptop CPU; the default 100M-scale config is sized for a
real accelerator.)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DecodeConfig, TrainConfig, get_config
from repro.core import Decoder
from repro.data import CharTokenizer, TaskDataset
from repro.training import train


def build_config(small: bool):
    base = get_config("llada-8b")
    if small:
        return base.reduced(num_layers=4, d_model=256, num_heads=4,
                            num_kv_heads=4, d_ff=1024)
    # ~100M: 12 layers, d_model 768 — the classic GPT-2-small geometry,
    # with the diffusion mask head
    import dataclasses
    return dataclasses.replace(
        base, name="llada-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=12, d_ff=3072, vocab_size=512, max_seq_len=128,
        dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--task", default="sort")
    ap.add_argument("--small", action="store_true")
    args = ap.parse_args()

    cfg = build_config(args.small)
    tok = CharTokenizer(cfg.vocab_size)
    ds = TaskDataset(args.task, tok)
    print(f"model: {cfg.name}  {cfg.param_count() / 1e6:.1f} M params")

    eval_batch = ds.eval_batch(32)
    prompts = jnp.asarray(ds.prompts_only(eval_batch))
    gen = ds.seq_len - prompts.shape[1]

    def eval_fn(params, step):
        dcfg = DecodeConfig(gen_length=gen, block_size=gen, steps=gen,
                            strategy="fdm_a")
        out, stats = Decoder(params, cfg, dcfg).generate(
            jax.random.PRNGKey(0), prompts)
        em = ds.exact_match(np.asarray(jax.device_get(out)), eval_batch)
        print(f"  [eval @ {step}] fdm_a exact-match {em:.2%} "
              f"tps {stats.tps:.1f}")

    tcfg = TrainConfig(batch_size=32, seq_len=ds.seq_len, steps=args.steps,
                       log_every=50, eval_every=100,
                       ckpt_dir="/tmp/repro_e2e")
    params, history = train(cfg, tcfg, ds.batches(tcfg.batch_size),
                            eval_fn=eval_fn)
    print("final:", history["loss"][-1])
    eval_fn(params, tcfg.steps)


if __name__ == "__main__":
    main()
