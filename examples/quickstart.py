"""Quickstart: train a tiny masked-diffusion LM on arithmetic, then decode
the same prompts with a heuristic order and with FDM to see the difference.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DecodeConfig, TrainConfig, get_config
from repro.core import Decoder
from repro.data import CharTokenizer, TaskDataset
from repro.training import train


def main():
    # 1. a reduced config from the paper's own model family
    cfg = get_config("llada-8b").reduced(num_layers=4, d_model=256,
                                         num_heads=4, num_kv_heads=4,
                                         d_ff=1024)
    tok = CharTokenizer(cfg.vocab_size)
    ds = TaskDataset("sum", tok)

    # 2. train on the Eq. 4 masked-diffusion objective
    tcfg = TrainConfig(batch_size=64, seq_len=ds.seq_len, steps=250,
                       log_every=50)
    print(f"training {cfg.param_count() / 1e6:.1f} M-param LLDM on 'sum' …")
    params, _ = train(cfg, tcfg, ds.batches(tcfg.batch_size))

    # 3. decode held-out prompts with two strategies through the
    # first-class Decoder (strategies are registry names; compiled
    # runners are shared across calls via the params-keyed cache)
    batch = ds.eval_batch(32)
    prompts = jnp.asarray(ds.prompts_only(batch))
    gen = ds.seq_len - prompts.shape[1]
    for strategy in ["probability", "fdm"]:
        dcfg = DecodeConfig(gen_length=gen, block_size=gen, steps=gen,
                            strategy=strategy, k=3)
        decoder = Decoder(params, cfg, dcfg)
        out, stats = decoder.generate(jax.random.PRNGKey(0), prompts)
        em = ds.exact_match(np.asarray(jax.device_get(out)), batch)
        print(f"{strategy:12s} exact-match {em:.2%}  "
              f"({stats.tokens_per_forward:.2f} tokens/forward)")
        for i in range(2):
            print(f"   {tok.decode(prompts[i])!r} -> "
                  f"{tok.decode(np.asarray(out)[i][ds.answer_slice])!r}")


if __name__ == "__main__":
    main()
