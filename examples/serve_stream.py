"""Async serving example: boot the HTTP/SSE front end in-process, then
talk to it like a real client — streamed blocks, per-request decode
knobs, admission control, and the metrics endpoint.

    PYTHONPATH=src python examples/serve_stream.py [--strategy fdm_a]

(For the standalone server CLI, see ``python -m repro.launch.serve``.)
"""
import argparse

from repro.configs import (DecodeConfig, RouterConfig, ServerConfig,
                           TrainConfig, default_block_size, get_config)
from repro.data import CharTokenizer, TaskDataset
from repro.serving import (ModelRouter, ServerThread, ServingClient,
                           ServingEngine)
from repro.training import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="fdm_a")
    ap.add_argument("--train-steps", type=int, default=250)
    args = ap.parse_args()

    cfg = get_config("llada-8b").reduced(num_layers=4, d_model=256,
                                         num_heads=4, num_kv_heads=4,
                                         d_ff=1024)
    tok = CharTokenizer(cfg.vocab_size)
    ds = TaskDataset("sum", tok)
    tcfg = TrainConfig(batch_size=64, seq_len=ds.seq_len,
                       steps=args.train_steps, log_every=100)
    print("warm-up training …")
    params, _ = train(cfg, tcfg, ds.batches(tcfg.batch_size))

    gen = ds.seq_len - (1 + ds.prompt_len)
    dcfg = DecodeConfig(gen_length=gen,
                        block_size=default_block_size(gen),
                        steps=gen, strategy=args.strategy)
    router = ModelRouter(RouterConfig())
    router.register("sum", lambda: ServingEngine(params, cfg, dcfg,
                                                 max_batch=4))
    handle = ServerThread(router, ServerConfig(port=0),
                          tokenizer=tok).start()
    print(f"serving on http://{handle.host}:{handle.port}")
    try:
        client = ServingClient(handle.host, handle.port)
        prompts = ds.prompts_only(ds.eval_batch(3))

        # 1) SSE: blocks stream as they commit (the natural grain of
        #    blockwise diffusion decoding)
        prompt = prompts[0].tolist()
        print(f"\nstreaming {tok.decode(prompt)!r}:")
        for name, event in client.generate_stream(prompt):
            if name == "block":
                print(f"  block {event['block']} "
                      f"[{event['lo']}:{event['hi']}] -> "
                      f"{event.get('text', event['tokens'])!r}")
            else:
                print(f"  {name}: {event.get('status')} in "
                      f"{event.get('latency_s', 0):.3f}s "
                      f"({event['stats']['steps']} steps)")

        # 2) per-request decode knobs ride the request
        res = client.generate(prompts[1].tolist(), strategy="probability",
                              wait=True)
        print(f"\nprobability override -> "
              f"{tok.decode(res['tokens'][-gen:])!r} "
              f"({res['stats']['forward_equivalents']:.1f} fwd-eq)")

        # 3) blocking call with the engine default
        res = client.generate(prompts[2].tolist(), wait=True)
        print(f"default ({args.strategy}) -> "
              f"{tok.decode(res['tokens'][-gen:])!r}")

        print("\nmetrics (head):")
        print("\n".join(client.metrics_text().splitlines()[:10]))
    finally:
        handle.stop()


if __name__ == "__main__":
    main()
