"""Batched serving example: the ServingEngine decoding queued requests
with FDM-A, reporting latency/throughput like a real endpoint.

    PYTHONPATH=src python examples/serve_batch.py [--strategy fdm_a]
"""
import argparse

import numpy as np

from repro.configs import DecodeConfig, TrainConfig, get_config
from repro.data import CharTokenizer, TaskDataset
from repro.serving import ServingEngine
from repro.training import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="fdm_a")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--train-steps", type=int, default=250)
    args = ap.parse_args()

    cfg = get_config("llada-8b").reduced(num_layers=4, d_model=256,
                                         num_heads=4, num_kv_heads=4,
                                         d_ff=1024)
    tok = CharTokenizer(cfg.vocab_size)
    ds = TaskDataset("sum", tok)
    tcfg = TrainConfig(batch_size=64, seq_len=ds.seq_len,
                       steps=args.train_steps, log_every=100)
    print("warm-up training …")
    params, _ = train(cfg, tcfg, ds.batches(tcfg.batch_size))

    gen = ds.seq_len - (1 + ds.prompt_len)
    dcfg = DecodeConfig(gen_length=gen, block_size=gen, steps=gen,
                        strategy=args.strategy)
    engine = ServingEngine(params, cfg, dcfg, max_batch=4)

    batch = ds.eval_batch(args.requests)
    prompts = ds.prompts_only(batch)
    print(f"submitting {args.requests} requests …")
    rids = [engine.submit(prompts[i]) for i in range(args.requests)]
    engine.run_until_idle()

    outs = np.stack([engine.result(r).result for r in rids])
    em = ds.exact_match(outs, batch)
    print(f"strategy={args.strategy} exact-match {em:.2%}")
    print("summary:", engine.summary())
    for r in rids[:3]:
        req = engine.result(r)
        print(f"  req {r}: {tok.decode(req.prompt)!r} -> "
              f"{tok.decode(req.result[ds.answer_slice])!r} "
              f"({req.latency:.2f}s)")


if __name__ == "__main__":
    main()
