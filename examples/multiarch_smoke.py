"""Every assigned architecture, one reduced forward + one FDM decode step —
the zoo tour.  Shows that the paper's technique is architecture-agnostic
(it only needs the all-masked-positions score map).

    PYTHONPATH=src python examples/multiarch_smoke.py
"""
import jax

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import fully_masked, make_model_fn, score_logits
from repro.core.fdm import fdm_select
from repro.models.model import init_model


def main():
    rng = jax.random.PRNGKey(0)
    for arch in ASSIGNED_ARCHS:
        rng, init_key, enc_key, patch_key, prompt_key = \
            jax.random.split(rng, 5)
        cfg = get_config(arch).reduced()
        params = init_model(init_key, cfg)
        kw = {}
        if cfg.is_encdec:
            kw["enc_embeds"] = jax.random.normal(
                enc_key,
                (2, min(cfg.encdec.encoder_seq, 32) or 32, cfg.d_model))
        if cfg.encdec is not None and cfg.encdec.frontend == "vision_stub":
            kw["patch_embeds"] = jax.random.normal(
                patch_key, (2, cfg.encdec.num_patch_tokens, cfg.d_model))
        prompt = jax.random.randint(prompt_key, (2, 4), 0,
                                    cfg.vocab_size - 1)
        x = fully_masked(cfg, prompt, 12)
        model_fn = make_model_fn(params, cfg, **kw)
        logits = model_fn(x)
        active = x == cfg.mask_token_id
        new_x, _ = fdm_select(x, logits, active, model_fn, cfg,
                              k=2, gamma=0.0, n=1)
        committed = int((new_x != cfg.mask_token_id).sum() -
                        (x != cfg.mask_token_id).sum())
        s = score_logits(logits)
        print(f"{arch:18s} [{cfg.arch_type:6s}] "
              f"logits {tuple(logits.shape)}  fdm committed {committed} "
              f"tok/example  max-prob {float(s.max_prob.mean()):.3f}")


if __name__ == "__main__":
    main()
